"""The paper's MMA-based parallel reduction, as a pure-JAX algorithm.

Carrasco, Vega & Navarro (2019) encode the reduction of ``n`` numbers as a
hierarchy of matrix-multiply-accumulate (MMA) operations:

  MMA 1:  ``D  = A @ 1 + 0``   (eq. 9-10)  -- row-sums of an m x m data tile,
                                              replicated across columns.
  MMA 2:  ``D' = 1 @ D + 0``   (eq. 11-12) -- column-sum of the row-sums; every
                                              entry of D' is the group total.

Each 2-MMA pass collapses a group of ``m**2`` elements to one value; the
recurrence ``R_tc(X) = R_tc(M(g_1), ..., M(g_k))`` (eq. 13) repeats until one
group remains, giving ``T_tc(n) = 5 * log_{m^2}(n)`` model steps (eq. 15-16).

On TPU the natural tile is the 128x128 MXU systolic pass (m = 128, one pass
reduces 16 384 elements); multiplications run in bf16 with f32 accumulation
(``preferred_element_type``), mirroring the tensor cores' fp16xfp16->fp32 mode.

This module is the *algorithmic* implementation (jnp only, runs anywhere and
differentiates); ``repro.kernels.mma_reduce`` is the Pallas TPU kernel with
explicit VMEM BlockSpec tiling that implements the same contract.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Sequence

import jax
import jax.numpy as jnp

# Default linear MMA tile size. 128 is the TPU MXU systolic dimension; the
# paper uses m=16 (WMMA API tile) / m=4 (V100 hardware tile). Tests sweep all.
DEFAULT_M = 128


@dataclasses.dataclass(frozen=True)
class ReductionTrace:
    """Instrumentation record for one hierarchical reduction.

    ``levels``     -- number of 2-MMA passes executed (recursion depth).
    ``model_steps``-- cost in the paper's unit model: 5 per level (read, fill,
                      MMA, MMA, write); eq. (15).
    ``mma_ops``    -- total m x m MMA operations issued across all levels.
    ``n``, ``m``   -- problem size and tile size.

    Multi-core (striped Pallas kernels; defaults describe the serial jnp
    hierarchy, so existing constructors are unchanged):
    ``num_cores``       -- lanes of the ("parallel", "arbitrary") grid.
    ``lane_mma_ops``    -- main-stream MMAs issued PER LANE (concurrent).
    ``combine_mma_ops`` -- trailing collapse/flush MMAs (the serial tail).
    ``hbm_bytes``       -- modeled HBM traffic of the pass
                          (``cost_model.hbm_bytes``; 0 = not modeled). The
                          zero-copy kernels move n*itemsize + O(c m^2); the
                          traces are asserted against the model so kernel
                          geometry and traffic accounting cannot diverge.
    ``fallback``        -- "" when the pass ran its advertised zero-copy
                          route; otherwise the NAME of the documented
                          degradation taken. Currently emitted:
                          "ingest_f32" (the f64/int/bool pre-cast in
                          ``ops._ingest``). The two other documented
                          degradations never reach a traced launch: the
                          past-``PARTS_KERNEL_MAX`` packed-stream fallback
                          and the batched-row-moments dot both run as plain
                          jnp code in ``backends.py`` (no kernel pass, so
                          no trace) -- they are documented at their call
                          sites instead.
    ``census``          -- True when the pass also carried the in-kernel
                          NON-FINITE census (NaN/Inf counts riding the same
                          tiles; its extra f32 output slots are already
                          folded into ``hbm_bytes``, and its input bytes
                          are zero by construction).
    """

    n: int
    m: int
    levels: int
    mma_ops: int
    num_cores: int = 1
    lane_mma_ops: int = 0
    combine_mma_ops: int = 0
    hbm_bytes: int = 0
    fallback: str = ""
    census: bool = False

    @property
    def model_steps(self) -> int:
        return 5 * self.levels

    @property
    def predicted_steps(self) -> float:
        """Paper eq. (16): T_tc(n) = 5 log_{m^2}(n)."""
        return 5.0 * math.log(max(self.n, 2), self.m**2)


def _two_mma_pass(
    tiles: jax.Array, m: int, compute_dtype: jnp.dtype, accum_dtype: jnp.dtype
) -> jax.Array:
    """One 2-MMA pass over a batch of m x m tiles: (k, m, m) -> (k,).

    Faithful to eqs. (9)-(12): B and the second-pass A are *all-ones m x m
    matrices*; we deliberately compute the full redundant product (the paper
    argues full-matrix MMA beats filtering a single column, and on the MXU the
    128 result lanes are produced by the same systolic pass anyway) and then
    read entry (0, 0).
    """
    ones = jnp.ones((m, m), dtype=compute_dtype)
    a = tiles.astype(compute_dtype)
    # MMA 1: D = A x 1 + 0, accumulated at f32 like the tensor-core D matrix.
    d = jax.lax.dot_general(
        a,
        jnp.broadcast_to(ones, a.shape),
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=accum_dtype,
    )
    # MMA 2: D' = 1 x D + 0. D re-enters at compute precision (the hardware
    # multiplies at bf16/fp16); accumulation stays f32.
    d = d.astype(compute_dtype)
    d2 = jax.lax.dot_general(
        jnp.broadcast_to(ones, d.shape),
        d,
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=accum_dtype,
    )
    return d2[:, 0, 0]


def mma_sum(
    x: jax.Array,
    *,
    m: int = DEFAULT_M,
    compute_dtype: jnp.dtype | None = None,
    accum_dtype: jnp.dtype = jnp.float32,
    trace: list[ReductionTrace] | None = None,
) -> jax.Array:
    """Reduce ``x`` to a scalar with the paper's hierarchical 2-MMA algorithm.

    The driver is the recurrence of eq. (13): split into groups of ``m**2``,
    reduce each group with two MMAs, recurse on the partials until one group
    is left. Group padding is with zeros (additive identity).

    Args:
      x: array of any shape; reduced over all elements.
      m: linear MMA tile size (>= 2). 128 = TPU MXU; 16 = WMMA; 4 = V100 HW.
      compute_dtype: dtype fed to the MMA multipliers (bf16 mimics hardware;
        default: bf16 for floating inputs of width <= 32, else x.dtype).
      accum_dtype: accumulator dtype (f32, like tensor cores' D matrix).
      trace: optional list; if given, a ReductionTrace is appended (Python
        metadata only -- does not affect the compiled computation).

    Returns:
      Scalar of ``accum_dtype``.
    """
    if m < 2:
        raise ValueError(f"m must be >= 2 (paper section V); got {m}")
    if compute_dtype is None:
        if jnp.issubdtype(x.dtype, jnp.floating):
            compute_dtype = jnp.bfloat16 if x.dtype != jnp.float64 else jnp.float64
        else:
            compute_dtype = jnp.float32
    group = m * m
    flat = x.reshape(-1).astype(accum_dtype)
    if flat.size == 0:
        # Empty reduction: the additive identity, zero levels (a degenerate
        # pad would otherwise loop on a (0, m, m) tile batch).
        if trace is not None:
            trace.append(ReductionTrace(n=0, m=m, levels=0, mma_ops=0))
        return jnp.zeros((), accum_dtype)
    levels = 0
    mma_ops = 0
    n0 = flat.size
    while flat.size > 1:
        k = -(-flat.size // group)  # ceil division: number of m^2 groups
        pad = k * group - flat.size
        if pad:
            flat = jnp.pad(flat, (0, pad))
        tiles = flat.reshape(k, m, m)
        flat = _two_mma_pass(tiles, m, compute_dtype, accum_dtype)
        levels += 1
        mma_ops += 2 * k
    if trace is not None:
        trace.append(ReductionTrace(n=n0, m=m, levels=levels, mma_ops=mma_ops))
    return flat.reshape(())


def mma_mean(x: jax.Array, **kw) -> jax.Array:
    return mma_sum(x, **kw) / x.size


def classic_tree_sum(
    x: jax.Array,
    *,
    accum_dtype: jnp.dtype = jnp.float32,
    trace: list[ReductionTrace] | None = None,
) -> jax.Array:
    """The classic pairwise GPU reduction (Nickolls/Harris), the paper's baseline.

    ``x[i] += x[i + p/2]`` halving passes; T(n) = 4 log2(n) in the paper's
    cost model (read, read, add, write per level). Implemented so that the
    summation *tree* matches the CUDA kernel's exactly (power-of-two halving
    with zero padding), which matters for the precision study.
    """
    flat = x.reshape(-1).astype(accum_dtype)
    n0 = flat.size
    if n0 == 0:
        if trace is not None:
            trace.append(ReductionTrace(n=0, m=2, levels=0, mma_ops=0))
        return jnp.zeros((), accum_dtype)
    size = 1 << max(0, (n0 - 1).bit_length())
    if size != flat.size:
        flat = jnp.pad(flat, (0, size - flat.size))
    levels = 0
    while flat.size > 1:
        half = flat.size // 2
        flat = flat[:half] + flat[half:]
        levels += 1
    if trace is not None:
        # m=2 so that model_steps/levels line up with the 4-per-level model;
        # mma_ops is 0 -- the classic algorithm issues none.
        trace.append(ReductionTrace(n=n0, m=2, levels=levels, mma_ops=0))
    return flat.reshape(())


# ---------------------------------------------------------------------------
# Row-wise (last-axis) reductions: the framework-facing primitives.
#
# Eq. (9)'s first MMA *is* a row-sum: D = X @ 1 puts sum_j X[i, j] in every
# column of row i. On the MXU a (R, L) x (L, 128) product costs the same
# systolic pass as any narrower RHS (lane width is 128), so the redundant
# columns are architecturally free -- this is the paper's "full MMA beats
# filtering" argument transplanted to TPU.
# ---------------------------------------------------------------------------


def _ones_rhs(length: int, width: int, dtype: jnp.dtype) -> jax.Array:
    return jnp.ones((length, width), dtype=dtype)


def row_sum_mma(
    x: jax.Array,
    *,
    compute_dtype: jnp.dtype = jnp.bfloat16,
    accum_dtype: jnp.dtype = jnp.float32,
    mxu_width: int = 128,
) -> jax.Array:
    """Sum over the last axis via a single all-ones MMA (paper eq. 9).

    (..., L) -> (...,): computes ``X @ ones(L, mxu_width)`` with f32
    accumulation and reads lane 0.
    """
    length = x.shape[-1]
    ones = _ones_rhs(length, mxu_width, compute_dtype)
    out = jax.lax.dot_general(
        x.astype(compute_dtype),
        ones,
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=accum_dtype,
    )
    return out[..., 0]


def row_moments_mma(
    x: jax.Array,
    *,
    compute_dtype: jnp.dtype = jnp.bfloat16,
    accum_dtype: jnp.dtype = jnp.float32,
    mxu_width: int = 128,
) -> tuple[jax.Array, jax.Array]:
    """(sum, sum-of-squares) over the last axis, both as all-ones MMAs.

    These two moments are exactly the statistics LayerNorm / RMSNorm need;
    this is the framework's normalization reduction path. The square is an
    elementwise (VPU) op; both reductions ride the MXU.
    """
    length = x.shape[-1]
    ones = _ones_rhs(length, mxu_width, compute_dtype)
    xc = x.astype(compute_dtype)
    stacked = jnp.stack([xc, (x.astype(accum_dtype) ** 2).astype(compute_dtype)], 0)
    out = jax.lax.dot_general(
        stacked,
        ones,
        (((stacked.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=accum_dtype,
    )
    return out[0, ..., 0], out[1, ..., 0]


# ---------------------------------------------------------------------------
# Differentiable public entry point. The VJP of a sum is a broadcast of the
# cotangent, independent of the reduction schedule, so we can give the
# hierarchical algorithm an exact, cheap gradient.
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def mma_sum_diff(x: jax.Array, m: int = DEFAULT_M) -> jax.Array:
    return mma_sum(x, m=m)


def _mma_sum_fwd(x, m):
    # zero-size residual carries shape+dtype without retaining x
    return mma_sum(x, m=m), jnp.zeros((0,) + x.shape, x.dtype)


def _mma_sum_bwd(m, res, g):
    return (jnp.broadcast_to(g, res.shape[1:]).astype(res.dtype),)


mma_sum_diff.defvjp(_mma_sum_fwd, _mma_sum_bwd)


def mma_sum_axis(
    x: jax.Array, axis: int | Sequence[int], *, m: int = DEFAULT_M, **kw
) -> jax.Array:
    """Reduce selected axes with the MMA path, keeping the rest batched.

    Moves the reduced axes last, flattens them, and applies the hierarchical
    row reduction (single MMA pass while the reduced extent <= m^2, recursing
    via mma_sum semantics otherwise).
    """
    axes = (axis,) if isinstance(axis, int) else tuple(axis)
    axes = tuple(a % x.ndim for a in axes)
    keep = tuple(a for a in range(x.ndim) if a not in axes)
    xt = jnp.transpose(x, keep + axes)
    batch_shape = xt.shape[: len(keep)]
    red = int(math.prod(xt.shape[len(keep):])) if axes else 1
    flat = xt.reshape(batch_shape + (red,))
    out = row_sum_mma(flat, **kw)
    # Hierarchical: row_sum_mma accumulates exactly once over the reduced
    # extent; for very long extents the Pallas kernel tiles it, but the jnp
    # algorithm can rely on XLA's single dot. Cost model still counts it as
    # ceil(log_{m^2}) levels in benchmarks (see bench_steps).
    return out


def global_norm_sq_mma(tree, *, m: int = DEFAULT_M) -> jax.Array:
    """Sum of squares over a whole pytree via the MMA path.

    Thin delegate: the sharding-critical per-leaf last-axis reduction lives
    in ``repro.reduce.reduce_tree`` (one implementation; see its docstring
    for the 169 GB all-gather rationale). Kept so pre-engine callers keep
    one numerical behavior with the engine path.
    """
    from repro.reduce import reduce_tree  # deferred: engine imports this module

    return reduce_tree(tree, kind="sumsq", backend="mma_jnp", m=m)
