"""The paper's primary contribution: MMA-encoded parallel reductions.

Public surface:
  mma_sum / mma_mean / mma_sum_axis / mma_sum_diff -- hierarchical 2-MMA
      reduction (Carrasco et al. 2019), TPU MXU-shaped (m=128 default).
  row_sum_mma / row_moments_mma -- single-MMA row reductions (norm stats).
  classic_tree_sum -- the paper's pairwise baseline (also the precision ref).
  cost_model -- T_tc(n)=5log_{m^2}n, S=(4/5)log2(m^2), TPU roofline terms.
  collectives -- the hierarchy continued across mesh axes (+ compression).
  precision -- Kahan / blocked-Kahan refinements and error metrics.
"""

from repro.core.mma_reduce import (  # noqa: F401
    DEFAULT_M,
    ReductionTrace,
    classic_tree_sum,
    global_norm_sq_mma,
    mma_mean,
    mma_sum,
    mma_sum_axis,
    mma_sum_diff,
    row_moments_mma,
    row_sum_mma,
)
from repro.core import cost_model, collectives, precision  # noqa: F401
