"""The paper's primary contribution: MMA-encoded parallel reductions.

The public entry point is ``repro.reduce`` (re-exported here as
``repro.core.reduce``): one ``reduce(x, axis=..., kind=...)`` dispatch layer
over every MMA-reduction path, with a cost-model-driven planner. The modules
in this package are the *backend implementations* behind it:

  mma_reduce -- hierarchical 2-MMA reduction (Carrasco et al. 2019) and the
      eq. (9) all-ones row reductions, as pure-JAX dots.
  cost_model -- T_tc(n)=5log_{m^2}n, S=(4/5)log2(m^2), TPU roofline terms
      (feeds the planner's backend selection).
  collectives -- the hierarchy continued across mesh axes (+ compression).
  precision -- Kahan / blocked-Kahan refinements and error metrics (feeds
      the engine's ``precision="kahan"`` policy).

The legacy per-path names (``mma_sum``, ``row_sum_mma``,
``global_norm_sq_mma``, ...) remain importable from here as thin deprecation
shims; new code should call ``repro.reduce.reduce`` / ``reduce_tree``.
"""

import functools as _functools
import warnings as _warnings

from repro.core.mma_reduce import DEFAULT_M, ReductionTrace  # noqa: F401
from repro.core import cost_model, collectives, precision  # noqa: F401
from repro.core import mma_reduce as _impl
from repro import reduce  # noqa: F401  -- the public reduction engine


def _deprecated(name: str, fn, hint: str):
    @_functools.wraps(fn)
    def wrapper(*args, **kwargs):
        _warnings.warn(
            f"repro.core.{name} is deprecated; use {hint}",
            DeprecationWarning,
            stacklevel=2,
        )
        return fn(*args, **kwargs)

    return wrapper


mma_sum = _deprecated(
    "mma_sum", _impl.mma_sum, 'repro.reduce.reduce(x, kind="sum")'
)
mma_mean = _deprecated(
    "mma_mean", _impl.mma_mean, 'repro.reduce.reduce(x, kind="mean")'
)
mma_sum_axis = _deprecated(
    "mma_sum_axis", _impl.mma_sum_axis, "repro.reduce.reduce(x, axis=...)"
)
mma_sum_diff = _deprecated(
    "mma_sum_diff", _impl.mma_sum_diff, "repro.reduce.reduce (differentiable)"
)
classic_tree_sum = _deprecated(
    "classic_tree_sum",
    _impl.classic_tree_sum,
    'repro.reduce.reduce(x, backend="xla") (or repro.core.mma_reduce.'
    "classic_tree_sum for the precision-study tree)",
)
row_sum_mma = _deprecated(
    "row_sum_mma", _impl.row_sum_mma, "repro.reduce.reduce(x, axis=-1)"
)
row_moments_mma = _deprecated(
    "row_moments_mma",
    _impl.row_moments_mma,
    'repro.reduce.reduce(x, axis=-1, kind="moments")',
)
global_norm_sq_mma = _deprecated(
    "global_norm_sq_mma",
    _impl.global_norm_sq_mma,
    'repro.reduce.reduce_tree(tree, kind="sumsq")',
)
