"""Cost models: the paper's step model and its TPU roofline extension.

Paper model (section IV.B, simplified GPU/PRAM model):
  coalesced r/w = 1, tile fill = 1, MMA = 1 cycle, result write = 1
  => T_tc(n) = 5 log_{m^2}(n)       (eq. 16)
     T_classic(n) = 4 log_2(n)      (pairwise baseline)
     S = (4/5) log_2(m^2)           (eq. 17)

TPU extension: the paper's model has no bandwidth or pipe-depth term. We add
both so EXPERIMENTS.md can say *where* the MMA encoding wins on real silicon:
a cold HBM-resident sum is bandwidth-bound and no compute trick helps; a
VMEM-resident (fused-epilogue) reduction is compute-unit-bound and moving it
from the VPU to the MXU is the win the paper predicts.
"""

from __future__ import annotations

import dataclasses
import math

# --- TPU v5e hardware constants (per chip), per the assignment spec ---------
PEAK_BF16_FLOPS = 197e12  # FLOP/s
HBM_BW = 819e9            # bytes/s
ICI_BW = 50e9             # bytes/s per link
MXU_DIM = 128             # systolic array linear size
VPU_LANES = 8 * 128       # VPU operates on (8, 128) vregs
# An MXU pass of (128,128)x(128,128) retires in ~MXU_DIM cycles once the
# pipeline is full; a VPU vector op retires VPU_LANES lanes/cycle.
CLOCK_HZ = 0.94e9         # v5e core clock (approx, public)


# ----------------------------- paper's model --------------------------------

def t_tensor_core(n: float, m: int) -> float:
    """Paper eq. (16): T_tc(n) = 5 log_{m^2}(n), in model steps."""
    if n <= 1:
        return 0.0
    return 5.0 * math.log(n, m * m)


def t_classic(n: float) -> float:
    """Paper's classic pairwise reduction: T(n) = 4 log2(n)."""
    if n <= 1:
        return 0.0
    return 4.0 * math.log2(n)


def speedup_model(m: int) -> float:
    """Paper eq. (17): S = (4/5) log2(m^2). S>1 for every m >= 2."""
    return 0.8 * math.log2(m * m)


def levels(n: int, m: int) -> int:
    """Number of 2-MMA passes the hierarchical driver executes (exact)."""
    if n <= 1:
        return 0
    group, out = m * m, 0
    while n > 1:
        n = -(-n // group)
        out += 1
    return out


# ------------------- multi-core striped-pipeline model ----------------------
#
# The paper's T(n) = 5 log_{m^2}(n) assumes every tensor-core unit reduces in
# parallel. The striped fused kernel realizes that on TPU: the n/m^2 tile
# MMAs split across c concurrent lanes (one per core), each lane paying one
# MMA per tile plus one trailing collapse, and a fixed-order combine of the
# c lane partials closes the reduction. Critical-path MMA count per lane:
#   n/(m^2 c) + c  (the +c is the lane collapses + lane fold, serialized).


@dataclasses.dataclass(frozen=True)
class MmaOpCount:
    """Static MMA instrumentation for one striped fused/segmented pass."""

    n: int
    m: int
    num_cores: int    # effective lanes (clamped to the block count)
    lane: int         # main-stream MMAs issued per lane, all lanes concurrent
    combine: int      # collapse/flush MMAs beyond the main streams (chip-wide)
    # Collapse/flush MMAs on ONE lane's serial chain. For the fused kernel
    # the whole combine runs after every lane finishes (serial tail), so
    # this equals `combine`; for the segmented kernel flushes execute
    # INSIDE their lanes concurrently, so it is the worst lane's share.
    serial_tail: int | None = None

    @property
    def total(self) -> int:
        """MMAs issued chip-wide: lanes * per-lane + the combine work."""
        return self.num_cores * self.lane + self.combine

    @property
    def critical_path(self) -> int:
        """MMAs on the longest serial chain: one lane's stream + its tail."""
        return self.lane + (
            self.combine if self.serial_tail is None else self.serial_tail
        )


def stripe_geometry(tiles: int, tiles_per_block: int, num_cores: int):
    """(r, c, blocks_per_lane, padded_tiles) for a striped tile stream.

    THE source of truth for the lane geometry -- the Pallas kernels
    (``kernels.mma_reduce.kernel._lane_geometry``) and the bit-exact
    reference emulation both delegate here, so the grid the silicon runs
    and the grid this model charges for can never diverge."""
    r = max(1, min(tiles_per_block, tiles))
    blocks = -(-tiles // r)
    c = max(1, min(num_cores, blocks))
    blocks_per_lane = -(-blocks // c)
    return r, c, blocks_per_lane, r * c * blocks_per_lane


def fused_mma_ops(
    n: int,
    m: int = MXU_DIM,
    num_cores: int = 1,
    tiles_per_block: int = 8,
    dual: bool = False,
) -> MmaOpCount:
    """MMA count for the striped fused C-accumulator kernel.

    Per lane: padded-tiles/c main MMAs; combine: c lane collapses (one
    batched f32 MMA) + 1 lane fold, all after the lanes join (serial
    tail). ``num_cores=1`` recovers the serial fused count n/m^2 + 2.
    ``dual=True`` models the moments prologue's paired (x, x^2)
    accumulators: every tile costs two MMAs and the combine collapses both
    statistics, so lane and combine counts double."""
    tiles = max(1, -(-n // (m * m)))
    _, c, _, tpad = stripe_geometry(tiles, tiles_per_block, num_cores)
    k = 2 if dual else 1
    return MmaOpCount(
        n=n, m=m, num_cores=c, lane=k * (tpad // c), combine=k * (c + 1)
    )


@dataclasses.dataclass(frozen=True)
class ScanMmaOps:
    """Static MMA instrumentation for one striped triangular-scan pass.

    The scan kernel (Dakkak-style two-level scheme on a CONTIGUOUS lane
    partition: lane ci owns blocks [ci*bpl, (ci+1)*bpl)) issues, per tile,
    two carry MMAs -- T1 = X @ J (row sums broadcast) and D = Ls @ T1 (rows-
    before-i totals, whose corner yields the tile total) -- during BOTH the
    carry-reconstruction prefix and the owned stripe, plus one prefix MMA
    (R = X @ U) only on owned tiles. Lanes therefore do DIFFERENT amounts
    of work (lane ci re-streams ci*bpl blocks before its stripe), which is
    why this is not an ``MmaOpCount``: that class models uniform lanes."""

    n: int
    m: int
    num_cores: int       # effective lanes (clamped to the block count)
    tiles: int           # padded tile count (r * c * blocks_per_lane)
    lane_scan: int       # MMAs on one lane's OWNED stripe (3 per tile)
    carry_worst: int     # carry-phase MMAs on the LAST lane (2 per tile)

    @property
    def total(self) -> int:
        """MMAs issued chip-wide: every lane's stripe + all carry prefixes.

        sum_ci [3*tiles/c + 2*(tiles/c)*ci] = tiles * (c + 2) / ... exactly
        ``3*tiles + tiles*(c-1)`` -- the serial count ``3*tiles`` at c=1."""
        t_per = self.tiles // self.num_cores
        return self.num_cores * self.lane_scan + sum(
            2 * t_per * ci for ci in range(self.num_cores)
        )

    @property
    def critical_path(self) -> int:
        """MMAs on the longest serial chain: the last lane's carry prefix
        plus its owned stripe. Approaches ``2/3`` of the serial chain as c
        grows -- the carry re-stream costs 2 MMAs/tile where the full scan
        costs 3 -- and there is no cross-lane combine at all."""
        return self.carry_worst + self.lane_scan


def scan_mma_ops(
    n: int,
    m: int = MXU_DIM,
    num_cores: int = 1,
    tiles_per_block: int = 8,
) -> ScanMmaOps:
    """MMA count for the striped triangular-scan kernel (kernels/scan.py).

    Same ``stripe_geometry`` as the reduction kernels, but the lanes own
    CONTIGUOUS block ranges (a scan is order-dependent; striping would
    interleave carries). ``num_cores=1`` recovers the serial triangular
    count 3 * tiles: one T1 = X@J, one D = Ls@T1, one R = X@U per tile."""
    tiles = max(1, -(-n // (m * m)))
    _, c, bpl, tpad = stripe_geometry(tiles, tiles_per_block, num_cores)
    per_lane_tiles = tpad // c
    return ScanMmaOps(
        n=n,
        m=m,
        num_cores=c,
        tiles=tpad,
        lane_scan=3 * per_lane_tiles,
        carry_worst=2 * per_lane_tiles * (c - 1),
    )


def segmented_mma_ops(
    n: int,
    tiles: int,
    flushes: int,
    m: int = MXU_DIM,
    num_cores: int = 1,
    max_lane_flushes: int | None = None,
) -> MmaOpCount:
    """MMA count for the striped segmented gather kernel.

    The gather path stripes at TILE granularity (each grid step fetches one
    m^2-aligned source block through its scalar-prefetched cover map, so
    there is no multi-tile block depth): lane ci owns tiles ci, ci+C, ... .
    ``tiles`` is the aligned-cover tile count (ops.segment_cover_layout --
    at most one extra tile per non-aligned segment boundary over n/m^2).
    ``flushes`` is the TOTAL lane-aware boundary count (>= non-empty
    segments, <= segments * lanes -- one per lane-segment visit); each is
    one collapse MMA issued inside its lane, so the lanes flush
    concurrently and only the worst lane's share (``max_lane_flushes``,
    conservatively ``flushes`` when unknown) sits on the critical path.
    ``num_cores=1`` recovers the serial segmented count n/m^2 + S."""
    _, c, _, tpad = stripe_geometry(tiles, 1, num_cores)
    return MmaOpCount(
        n=n,
        m=m,
        num_cores=c,
        lane=tpad // c,
        combine=flushes,
        serial_tail=flushes if max_lane_flushes is None else max_lane_flushes,
    )


# --------------------------- HBM traffic model -------------------------------
#
# The reduction is memory-bound (see tpu_reduction_roofline below), so the
# quantity that decides wall time on real silicon is BYTES MOVED, not MMAs.
# The zero-copy kernels read the caller's buffer once, in its native dtype,
# and write only O(c m^2) partials; the pre-zero-copy ("staged") ingestion
# paid ~3x that for a bf16 operand: read n*2 (cast) + write n*4 (f32 staging
# copy) + read n*4 (kernel). These models are asserted against the geometry
# the kernels actually run (ops.py traces carry the modeled bytes, and
# benchmarks/check_bench.py re-derives the "measured" number from the lowered
# jaxpr's pallas_call operands), so model and silicon cannot drift silently.

_F32 = 4  # partials/accumulators/outputs are always f32


@dataclasses.dataclass(frozen=True)
class HbmTraffic:
    """Modeled HBM bytes for one reduction, split along the launch boundary.

    ``kernel_read`` / ``kernel_write`` -- operands DMA'd into and results
    written out of the pallas launch(es): exactly the avals crossing the
    ``pallas_call`` boundary, so ``launch_io`` can be asserted EQUAL to
    ``repro.reduce.inspect.pallas_io_bytes`` of the lowered program (the
    "traced geometry" check -- model and silicon cannot drift).
    ``stage_read`` / ``stage_write`` -- host-side staging copies before the
    launch (zero on every zero-copy path; the pre-zero-copy comparison
    model charges its cast+pad copy here).
    ``combine_read`` / ``combine_write`` -- the deterministic host-side
    lane/segment combine re-reading the partials and writing the result.
    ``refetch_read`` -- bytes a launch DMAs from HBM *again* beyond its
    operand avals (the scan kernel's carry-reconstruction prefix re-streams
    already-counted blocks through the same BlockSpec). These are real wire
    bytes but invisible to the aval accounting, so they are kept OUT of
    ``launch_io`` -- the ``pallas_io_bytes`` equality stays exact -- and
    charged in ``read``/``total``.
    """

    kernel_read: int
    kernel_write: int
    stage_read: int = 0
    stage_write: int = 0
    combine_read: int = 0
    combine_write: int = 0
    refetch_read: int = 0

    @property
    def launch_io(self) -> int:
        """Bytes crossing the pallas_call boundary (== pallas_io_bytes)."""
        return self.kernel_read + self.kernel_write

    @property
    def read(self) -> int:
        return (
            self.kernel_read + self.stage_read + self.combine_read
            + self.refetch_read
        )

    @property
    def write(self) -> int:
        return self.kernel_write + self.stage_write + self.combine_write

    @property
    def total(self) -> int:
        return self.read + self.write


def fused_hbm_bytes(
    n: int,
    itemsize: int,
    *,
    m: int = MXU_DIM,
    num_cores: int = 1,
    tiles_per_block: int = 8,
    kahan: bool = False,
    dual: bool = False,
    epilogue: bool = False,
) -> HbmTraffic:
    """Zero-copy fused pass: the kernel streams the caller's buffer once at
    native width (boundary blocks clip to the true length -- masked loads,
    not padded copies), writes C lane partials ((C, 2, m, m) under the Kahan
    carry or the moments dual accumulator -- ``dual=True``), and the host
    combine reads those partials back and writes the scalar (a (2,) pair
    for moments). Total = n*itemsize + O(c m^2): ingestion dominates,
    exactly the stream term of the roofline. The elementwise prologues
    (square/abs) change NO bytes -- that is the whole point: the sumsq /
    norm2 stream costs exactly what the plain sum costs. ``epilogue=True``
    is the in-kernel scalar finish (single-lane, non-kahan launches): the
    chain itself ADDS no bytes -- the lane-partial write and the host
    combine are replaced by one finished f32 scalar crossing the launch
    boundary."""
    tiles = max(1, -(-n // (m * m)))
    _, c, _, _ = stripe_geometry(tiles, tiles_per_block, num_cores)
    if epilogue:
        if c != 1 or kahan or dual:
            raise ValueError(
                "in-kernel fused epilogue requires a single-lane, "
                f"non-kahan, non-dual launch; got c={c}, kahan={kahan}, "
                f"dual={dual}"
            )
        return HbmTraffic(kernel_read=n * itemsize, kernel_write=_F32)
    partials = (2 if (kahan or dual) else 1) * c * m * m * _F32
    return HbmTraffic(
        kernel_read=n * itemsize,
        kernel_write=partials,
        combine_read=partials,
        combine_write=(2 if dual else 1) * _F32,
    )


def staged_sumsq_hbm_bytes(
    n: int,
    itemsize: int,
    *,
    m: int = MXU_DIM,
    num_cores: int = 1,
    tiles_per_block: int = 8,
) -> HbmTraffic:
    """The PRE-prologue sumsq/norm2 ingestion (kept as the benchmark
    comparison point): the host squared at f32 BEFORE the kernel --
    read n*itemsize (the native leaf) + write n*4 (the f32 squares) -- and
    the zero-copy kernel then streamed that f32 temporary instead of the
    caller's data. For bf16 that is read-n*2 + write-n*4 + read-n*4: ~5x
    the single-stream bytes of the in-kernel square prologue."""
    zc = fused_hbm_bytes(
        n, _F32, m=m, num_cores=num_cores, tiles_per_block=tiles_per_block
    )
    return HbmTraffic(
        kernel_read=zc.kernel_read,
        kernel_write=zc.kernel_write,
        stage_read=n * itemsize,
        stage_write=n * _F32,
        combine_read=zc.combine_read,
        combine_write=zc.combine_write,
    )


def staged_fused_hbm_bytes(
    n: int,
    itemsize: int,
    *,
    m: int = MXU_DIM,
    num_cores: int = 1,
    tiles_per_block: int = 8,
    kahan: bool = False,
) -> HbmTraffic:
    """The PRE-zero-copy ingestion (kept as the benchmark comparison point):
    ``reshape(-1).astype(f32)`` + ``pad_to`` materialized a padded f32 copy
    of the whole input before the launch -- read n*itemsize, write tpad*m^2
    f32 -- and the kernel then read that staging buffer instead of the
    caller's data. For bf16 that is read-n*2 + write-n*4 + read-n*4: ~3x
    the zero-copy bytes before any partial traffic."""
    tiles = max(1, -(-n // (m * m)))
    _, c, _, tpad = stripe_geometry(tiles, tiles_per_block, num_cores)
    staged = tpad * m * m * _F32
    partials = (2 if kahan else 1) * c * m * m * _F32
    return HbmTraffic(
        kernel_read=staged,
        kernel_write=partials,
        stage_read=n * itemsize,
        stage_write=staged,
        combine_read=partials,
        combine_write=_F32,
    )


def hier_hbm_bytes(
    n: int, itemsize: int, *, m: int = MXU_DIM, tiles_per_block: int = 8
) -> HbmTraffic:
    """Multi-launch hierarchy (eq. 13): level 0 streams the native buffer
    with masked-tail loads; every level writes its (block-padded) partials
    to HBM and the next level reads them back -- the round-trip the fused
    kernel removes."""
    group = m * m
    kread, kwrite, size, bs = 0, 0, max(n, 1), itemsize
    while size > 1:
        kread += size * bs
        t = -(-size // group)
        r = max(1, min(tiles_per_block, t))
        tpad = -(-t // r) * r  # the launch writes its padded partial row
        kwrite += tpad * _F32
        size = t
        bs = _F32
    return HbmTraffic(kernel_read=kread, kernel_write=kwrite)


def hier_moments_hbm_bytes(
    n: int, itemsize: int, *, m: int = MXU_DIM, tiles_per_block: int = 8
) -> HbmTraffic:
    """Multi-launch hierarchy under the moments dual-accumulator prologue:
    level 0 streams the native buffer ONCE and writes a (tpad, 2) partial
    pair (both statistics from one pass); the upper rungs then reduce each
    f32 column with the plain identity hierarchy."""
    group = m * m
    size = max(n, 1)
    t = -(-size // group)
    r = max(1, min(tiles_per_block, t))
    tpad = -(-t // r) * r
    upper = hier_hbm_bytes(t, _F32, m=m, tiles_per_block=tiles_per_block)
    return HbmTraffic(
        kernel_read=size * itemsize + 2 * upper.kernel_read,
        kernel_write=2 * tpad * _F32 + 2 * upper.kernel_write,
    )


def segmented_hbm_bytes(
    fetched_elems: int,
    itemsize: int,
    *,
    segments: int,
    tiles: int = 0,
    m: int = MXU_DIM,
    num_cores: int = 1,
) -> HbmTraffic:
    """Zero-copy segmented gather: every tile is a masked view of one
    m^2-aligned block of the caller's flat buffer, so ``fetched_elems`` is
    n plus at most one re-fetched block per non-aligned segment boundary
    (``ops.segment_cover_layout`` computes the exact count -- O(S m^2) over
    n). The launch also prefetches five (tpad,) int32 cover maps; it writes
    (C, S) sub-partials, which the combine reads back to produce the (S,)
    result. NOTE: ``launch_io`` here uses the FETCHED bytes; the lowered
    program's operand avals count the flat buffer once, so
    ``pallas_io_bytes`` == ``launch_io`` exactly when every boundary is
    tile-aligned and is a lower bound otherwise."""
    _, c, _, tpad = stripe_geometry(max(tiles, 1), 1, num_cores)
    maps = 5 * tpad * 4
    sub = c * segments * _F32
    return HbmTraffic(
        kernel_read=fetched_elems * itemsize + maps,
        kernel_write=sub,
        combine_read=sub,
        combine_write=segments * _F32,
    )


def parts_hbm_bytes(part_bytes: int, *, segments: int) -> HbmTraffic:
    """Zero-copy parts pass (``reduce_many``/``reduce_tree``): each of the S
    arrays enters the launch as its own operand -- no packing copy -- and is
    streamed once at native width (``part_bytes`` = sum of the live parts'
    nbytes; boundary blocks clip and dwelled blocks never re-DMA, so there
    is no padding traffic). The (S,) output is final: no combine. Epilogue
    total chains cost NO input bytes -- K finished scalars just widen
    ``segments`` by K output slots (callers pass segments + K)."""
    return HbmTraffic(kernel_read=part_bytes, kernel_write=segments * _F32)


def scan_hbm_bytes(
    n: int,
    itemsize: int,
    *,
    out_itemsize: int | None = None,
    m: int = MXU_DIM,
    num_cores: int = 1,
    tiles_per_block: int = 8,
) -> HbmTraffic:
    """Zero-copy triangular scan: the kernel streams the caller's native
    buffer once (masked boundary loads, no padding traffic on the operand
    side) and writes the FULL prefix array -- block-padded, in the output
    dtype -- which the caller slices back to n. A scan cannot shrink its
    output the way a reduction does, so the write side is O(n), not
    O(c m^2), and there is no host combine at all: the in-kernel carry
    chain finishes the result. ``refetch_read`` charges the carry-
    reconstruction prefix: lane ci re-streams blocks [0, ci*bpl) -- clipped
    to the real data extent -- to rebuild its exclusive carry without any
    cross-lane traffic (the Dakkak decoupled scheme's redundant-work trade:
    O(n) extra read bandwidth buys a combine-free, bitwise-deterministic
    multi-core scan)."""
    out_itemsize = itemsize if out_itemsize is None else out_itemsize
    tiles = max(1, -(-n // (m * m)))
    r, c, bpl, tpad = stripe_geometry(tiles, tiles_per_block, num_cores)
    block_elems = r * m * m
    refetch = sum(min(ci * bpl * block_elems, n) for ci in range(c))
    return HbmTraffic(
        kernel_read=n * itemsize,
        kernel_write=tpad * m * m * out_itemsize,
        refetch_read=refetch * itemsize,
    )


def staged_scan_hbm_bytes(
    n: int,
    itemsize: int,
    *,
    m: int = MXU_DIM,
    num_cores: int = 1,
    tiles_per_block: int = 8,
) -> HbmTraffic:
    """The XLA two-pass comparison point for a sub-f32 cumsum: XLA upcasts
    the operand to a materialized f32 copy (read n*itemsize + write n*4),
    scans that temporary at f32 (read n*4 + write n*4), and downcasts the
    result back to the storage dtype (read n*4 + write n*itemsize). For
    bf16 that is ~5x the single-stream bytes of the native-ingest kernel,
    the same ratio the staged-sumsq comparison showed for reductions."""
    zc = scan_hbm_bytes(
        n, _F32, out_itemsize=_F32, m=m, num_cores=num_cores,
        tiles_per_block=tiles_per_block,
    )
    return HbmTraffic(
        kernel_read=zc.kernel_read,
        kernel_write=zc.kernel_write,
        stage_read=n * itemsize,
        stage_write=n * _F32,
        combine_read=n * _F32,
        combine_write=n * itemsize,
        refetch_read=zc.refetch_read,
    )


# ------------------------- interconnect traffic ------------------------------


@dataclasses.dataclass(frozen=True)
class IciTraffic:
    """Modeled interconnect bytes for one deterministic fixed-order combine
    of ``slots`` f32 partials across a ``world``-device mesh.

    The combine is ONE all-gather per mesh axis: every device receives the
    other P-1 devices' partial rows and folds them locally in static device
    order (no reduction happens on the wire, which is exactly what buys
    bitwise reproducibility). ``recv_per_device`` is therefore
    ``(world - 1) * slots * itemsize`` for a single axis -- asserted EQUAL to
    ``repro.reduce.inspect.collective_recv_bytes`` of the lowered program,
    the same model==lowered discipline as ``HbmTraffic.launch_io``.
    """

    slots: int
    world: int
    itemsize: int = _F32

    @property
    def recv_per_device(self) -> int:
        """Wire bytes INTO each device (== inspect.collective_recv_bytes)."""
        return (self.world - 1) * self.slots * self.itemsize

    @property
    def send_per_device(self) -> int:
        """Wire bytes OUT of each device (its row to the other P-1)."""
        return (self.world - 1) * self.slots * self.itemsize

    @property
    def wire_total(self) -> int:
        """Total bytes on the interconnect across all devices."""
        return self.world * self.recv_per_device

    @property
    def time_s(self) -> float:
        """Lower-bound gather time on the paper-model link bandwidth."""
        return self.recv_per_device / ICI_BW

    def vs_psum_recv(self) -> float:
        """Cost ratio vs an idealized reduce-scatter+gather psum of the same
        row (which moves ~2 * slots * itemsize per device regardless of P).
        The fixed-order combine trades O(P) gather bytes for determinism;
        for the guard's slot counts (S + K + census) this is noise next to
        the shard's HBM traffic."""
        psum_recv = 2 * self.slots * self.itemsize
        return self.recv_per_device / max(psum_recv, 1)


def interconnect_bytes(
    slots: int, world: int, *, itemsize: int = _F32
) -> IciTraffic:
    """Interconnect traffic of the mesh_axes= reduce path: the per-device
    additive row (per-leaf slots + raw total + census counts) is all-gathered
    once and folded locally. ``world`` is the product of the mesh axis sizes;
    for multi-axis meshes combined one axis at a time the single-axis model
    applies per axis (callers sum per-axis instances)."""
    if slots < 0 or world < 1:
        raise ValueError(f"invalid interconnect geometry: {slots=} {world=}")
    return IciTraffic(slots=slots, world=world, itemsize=itemsize)


def hbm_bytes(
    path: str,
    n: int,
    itemsize: int,
    *,
    m: int = MXU_DIM,
    num_cores: int = 1,
    tiles_per_block: int = 8,
    kahan: bool = False,
    dual: bool = False,
    segments: int = 1,
    tiles: int = 0,
    fetched_elems: int | None = None,
    epilogue: bool = False,
    census: int = 0,
) -> HbmTraffic:
    """Dispatch over the traffic models above by execution path.

    ``path``: "fused" | "fused_staged" | "sumsq_staged" | "hier" |
    "hier_moments" | "segmented" | "parts" | "scan" | "scan_staged".
    For "segmented", ``fetched_elems`` (from the cover layout) defaults to
    ``n``; for "parts", ``n * itemsize`` must equal the summed native bytes
    of the live parts (heterogeneous dtypes: call parts_hbm_bytes).
    ``dual=True`` selects the moments pair-accumulator output shapes on the
    fused path; the elementwise prologues (square/abs) are byte-identical
    to their identity path and need no flag. ``epilogue=True`` (fused path)
    is the in-kernel scalar finish -- the chain adds 0 bytes and the launch
    emits one f32; on the parts path, epilogue total chains instead widen
    ``segments`` by the chain count. ``census`` (parts/segmented paths)
    counts the NON-FINITE-census output slots: like the epilogue chains,
    the census costs ZERO input bytes -- it rides the tiles already in
    registers -- and only widens the output row by ``census`` f32 slots
    (the parts consumer passes S + 1: per-part counts plus the total)."""
    if path == "fused":
        return fused_hbm_bytes(
            n, itemsize, m=m, num_cores=num_cores,
            tiles_per_block=tiles_per_block, kahan=kahan, dual=dual,
            epilogue=epilogue,
        )
    if path == "fused_staged":
        return staged_fused_hbm_bytes(
            n, itemsize, m=m, num_cores=num_cores,
            tiles_per_block=tiles_per_block, kahan=kahan,
        )
    if path == "sumsq_staged":
        return staged_sumsq_hbm_bytes(
            n, itemsize, m=m, num_cores=num_cores,
            tiles_per_block=tiles_per_block,
        )
    if path == "hier":
        return hier_hbm_bytes(
            n, itemsize, m=m, tiles_per_block=tiles_per_block
        )
    if path == "hier_moments":
        return hier_moments_hbm_bytes(
            n, itemsize, m=m, tiles_per_block=tiles_per_block
        )
    if path == "segmented":
        return segmented_hbm_bytes(
            fetched_elems if fetched_elems is not None else n,
            itemsize, segments=segments + census, tiles=tiles, m=m,
            num_cores=num_cores,
        )
    if path == "parts":
        return parts_hbm_bytes(n * itemsize, segments=segments + census)
    if path == "scan":
        return scan_hbm_bytes(
            n, itemsize, m=m, num_cores=num_cores,
            tiles_per_block=tiles_per_block,
        )
    if path == "scan_staged":
        return staged_scan_hbm_bytes(
            n, itemsize, m=m, num_cores=num_cores,
            tiles_per_block=tiles_per_block,
        )
    if path == "parts_2trip":
        # comparison model for the pre-epilogue optimizer step: the norm
        # launch streams the grads once, the host finishes sqrt/min, and
        # the elementwise update then reads every grad byte AGAIN -- two
        # HBM trips per leaf where the epilogue fork + fused second moment
        # need one
        base = parts_hbm_bytes(n * itemsize, segments=segments + census)
        return HbmTraffic(
            kernel_read=base.kernel_read + n * itemsize,
            kernel_write=base.kernel_write,
        )
    raise ValueError(f"unknown hbm_bytes path {path!r}")


# ----------------------------- TPU extension --------------------------------

@dataclasses.dataclass(frozen=True)
class ReductionRoofline:
    """Three-term roofline for reducing n elements of `bytes_per_el` on TPU."""

    n: int
    bytes_per_el: int
    hbm_s: float      # time to stream the operand from HBM once
    vpu_s: float      # time for a VPU tree reduction, operand in VMEM
    mxu_s: float      # time for the paper's MMA reduction, operand in VMEM

    @property
    def cold_bound_s(self) -> float:
        """A cold reduction can never beat the stream time."""
        return max(self.hbm_s, self.mxu_s)

    @property
    def fused_speedup(self) -> float:
        """VPU/MXU time ratio for a VMEM-resident (fused) reduction. ~0.8 at
        m=128: the MXU path is near-parity on raw time -- its value is that
        it runs on the otherwise-idle MXU, freeing 100% of VPU cycles for
        the surrounding kernel (the contended unit in norm/softmax fusions)."""
        return self.vpu_s / self.mxu_s if self.mxu_s else float("inf")

    @property
    def mxu_bandwidth_neutral(self) -> bool:
        """True when the MMA encoding adds no wall time over the HBM stream
        bound for cold operands (the common case at m=128/bf16)."""
        return self.mxu_s <= self.hbm_s * 1.15


def tpu_reduction_roofline(n: int, bytes_per_el: int = 2) -> ReductionRoofline:
    hbm_s = n * bytes_per_el / HBM_BW
    # VPU: streaming tree reduction retires VPU_LANES FMA lanes/cycle plus a
    # log-depth lane-fold tail. Peak VPU ~= 2 * VPU_LANES * CLOCK ~ 1.9 TF/s.
    vpu_cycles = n / VPU_LANES + 10 * math.log2(max(n, 2))
    vpu_s = vpu_cycles / CLOCK_HZ
    # MXU, *throughput* model: each 2-MMA pass over k tiles of m^2=16384
    # elements issues 2k matmuls of 2*m^3 FLOPs, pipelined at chip peak.
    # Per element that is 4m FLOPs; at m=128 and 197 TF/s the MXU reduction
    # runs within ~1.3x of the VPU's time while leaving the VPU fully idle --
    # and both sit at/under the HBM stream time for cold bf16 operands, so
    # the MMA encoding is bandwidth-neutral for cold data and a pure VPU
    # offload for fused (VMEM-resident) reductions.
    group = MXU_DIM * MXU_DIM
    mma_flops, remaining = 0.0, n
    while remaining > 1:
        k = -(-remaining // group)
        mma_flops += 2 * k * 2 * MXU_DIM**3
        remaining = k
    mxu_s = mma_flops / PEAK_BF16_FLOPS
    return ReductionRoofline(n, bytes_per_el, hbm_s, vpu_s, mxu_s)


# --------------------- step-model table (benchmarks) ------------------------

def model_table(ns=(2**10, 2**16, 2**20, 2**26, 2**30), ms=(2, 4, 16, 128)):
    """Rows of (n, m, T_tc, T_classic, S_model) for the paper's tables."""
    rows = []
    for n in ns:
        for m in ms:
            rows.append(
                dict(
                    n=n,
                    m=m,
                    t_tc=t_tensor_core(n, m),
                    t_classic=t_classic(n),
                    speedup=t_classic(n) / max(t_tensor_core(n, m), 1e-12),
                    speedup_closed_form=speedup_model(m),
                )
            )
    return rows
