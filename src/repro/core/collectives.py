"""Cross-device continuation of the paper's reduction hierarchy.

Eq. (13)'s recurrence does not care whether a "group" is an MXU tile or a
mesh axis: after the on-chip MMA hierarchy collapses a shard to one partial,
the same recurrence runs across `model` -> `data` -> `pod` mesh axes. These
helpers are written for use *inside* ``jax.shard_map`` bodies (they take axis
names); the pjit'd model path lets GSPMD insert its own collectives, while
the optimizer's explicit reductions (global norm, compressed gradient
exchange) route through here.

Includes the distributed-optimization tricks required at 1000+ node scale:
  * bucketed ring all-reduce (ppermute) -- overlappable with compute,
  * int8 error-feedback compressed psum for the thin cross-pod hop,
  * hierarchical reduce ordered thick-pipe-first.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.mma_reduce import DEFAULT_M

try:  # jax >= 0.5 promoted shard_map out of experimental
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - version compat
    from jax.experimental.shard_map import shard_map


def hierarchical_psum(x: jax.Array, axis_names: Sequence[str]) -> jax.Array:
    """psum over mesh axes in order (innermost/thickest link first).

    One psum per axis keeps each collective on its own ICI ring instead of a
    single global ring whose latency is set by the thinnest (cross-pod) hop.
    """
    for ax in axis_names:
        x = lax.psum(x, ax)
    return x


def local_mma_then_psum(
    x: jax.Array,
    axis_names: Sequence[str],
    *,
    m: int = DEFAULT_M,
    backend: Optional[str] = None,
) -> jax.Array:
    """Full scalar reduction of a sharded array: the reduction engine on the
    local shard, then the mesh-axis rungs. This is eq. (13) spanning the
    whole machine. ``backend=None`` defers to the engine's process-wide
    default (``--reduce-backend`` / $REPRO_REDUCE_BACKEND / planner)."""
    # local import: repro.core's package init imports this module, while the
    # engine imports repro.core submodules -- deferring breaks the cycle.
    from repro import reduce as R

    local = R.reduce(x, kind="sum", backend=backend, m=m)
    return hierarchical_psum(local, axis_names)


# ----------------------------- ring all-reduce ------------------------------


def ring_all_reduce(x: jax.Array, axis_name: str) -> jax.Array:
    """Bucketed ring all-reduce built from ppermute: reduce-scatter pass then
    all-gather pass, 2(P-1) hops, each hop moving |x|/P bytes.

    Written explicitly (rather than lax.psum) so the scheduler can overlap
    the per-hop sends with unrelated compute, and so the compressed variant
    below can quantize the wire format per hop.
    """
    try:
        p = lax.axis_size(axis_name)
    except AttributeError:  # pragma: no cover - jax<0.5: psum of a literal
        p = lax.psum(1, axis_name)
    if p == 1:
        return x
    idx = lax.axis_index(axis_name)
    flat = x.reshape(-1)
    pad = (-flat.size) % p
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(p, -1)
    perm = [(i, (i + 1) % p) for i in range(p)]

    def rs_step(t, chunks):
        # each rank accumulates into chunk (idx - t - 1) which it just received
        send_ix = (idx - t) % p
        recv_ix = (idx - t - 1) % p
        sent = lax.ppermute(chunks[send_ix], axis_name, perm)
        return chunks.at[recv_ix].add(sent)

    chunks = lax.fori_loop(0, p - 1, rs_step, chunks)

    def ag_step(t, chunks):
        send_ix = (idx - t + 1) % p
        recv_ix = (idx - t) % p
        sent = lax.ppermute(chunks[send_ix], axis_name, perm)
        return chunks.at[recv_ix].set(sent)

    chunks = lax.fori_loop(0, p - 1, ag_step, chunks)
    out = chunks.reshape(-1)
    if pad:
        out = out[: out.size - pad]
    return out.reshape(x.shape)


# ----------------------- compressed (int8 EF) psum ---------------------------


def compressed_psum(
    x: jax.Array, axis_name: str, err: jax.Array | None = None
) -> tuple[jax.Array, jax.Array]:
    """int8 error-feedback all-reduce for the thin cross-pod hop.

    Protocol: add carried error, agree on a shared scale via pmax, quantize
    to int8, psum in int32 (exact), dequantize. The local quantization
    residual is returned as the next step's error carry (EF-SGD; convergence
    preserved under standard assumptions). Wire bytes: 1/4 of f32, 1/2 of
    bf16 -- targeted at the `pod` axis whose link is the bottleneck.

    Returns (allreduced_f32, new_error_carry).
    """
    xf = x.astype(jnp.float32)
    if err is not None:
        xf = xf + err
    amax = lax.pmax(jnp.max(jnp.abs(xf)), axis_name)
    scale = jnp.maximum(amax / 127.0, 1e-30)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    new_err = xf - q.astype(jnp.float32) * scale
    qsum = lax.psum(q.astype(jnp.int32), axis_name)
    return qsum.astype(jnp.float32) * scale, new_err


def hierarchical_grad_reduce(
    grad: jax.Array,
    *,
    dense_axes: Sequence[str] = ("data",),
    compressed_axis: str | None = "pod",
    err: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array | None]:
    """Gradient all-reduce: exact psum on intra-pod axes, optional int8-EF on
    the cross-pod axis. Mean-normalization is left to the caller (it knows
    the global data-parallel degree)."""
    g = grad
    for ax in dense_axes:
        g = lax.psum(g, ax)
    if compressed_axis is not None:
        g, err = compressed_psum(g, compressed_axis, err)
    return g, err


def make_sharded_global_norm_sq(
    mesh: jax.sharding.Mesh, *, backend: Optional[str] = None
):
    """Global sum-of-squares of a sharded pytree: per-shard reduction through
    the engine (``reduce_tree``'s last-axis MMA path keeps every dot on the
    local shard), then the mesh rungs -- the optimizer's clipping statistic
    at scale."""
    axis_names = tuple(mesh.axis_names)

    def body(tree):
        from repro import reduce as R  # deferred: see local_mma_then_psum

        local = R.reduce_tree(tree, kind="sumsq", backend=backend)
        return hierarchical_psum(local, axis_names)

    return functools.partial(
        shard_map,
        body,
        mesh=mesh,
        in_specs=None,  # caller supplies per-leaf specs
        out_specs=jax.sharding.PartitionSpec(),
    )
