"""Cross-device continuation of the paper's reduction hierarchy.

Eq. (13)'s recurrence does not care whether a "group" is an MXU tile or a
mesh axis: after the on-chip MMA hierarchy collapses a shard to one partial,
the same recurrence runs across `model` -> `data` -> `pod` mesh axes. These
helpers are written for use *inside* ``jax.shard_map`` bodies (they take axis
names); the pjit'd model path lets GSPMD insert its own collectives, while
the optimizer's explicit reductions (global norm, compressed gradient
exchange) route through here.

Includes the distributed-optimization tricks required at 1000+ node scale:
  * bucketed ring all-reduce (ppermute) -- overlappable with compute,
  * int8 error-feedback compressed psum for the thin cross-pod hop,
  * hierarchical reduce ordered thick-pipe-first.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.mma_reduce import DEFAULT_M

try:  # jax >= 0.5 promoted shard_map out of experimental
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - version compat
    from jax.experimental.shard_map import shard_map


def shard_map_unchecked(body, *, mesh, in_specs, out_specs):
    """``shard_map`` with the replication checker off: pallas_call has no
    replication rule, so any per-device kernel launch inside a shard_map
    body trips it. The flag was renamed across jax versions (check_rep ->
    check_vma); try both so engine call sites stay version-portable."""
    for kw in ("check_rep", "check_vma"):
        try:
            return shard_map(
                body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                **{kw: False},
            )
        except TypeError:  # pragma: no cover - other jax version
            continue
    return shard_map(  # pragma: no cover - checker flag gone entirely
        body, mesh=mesh, in_specs=in_specs, out_specs=out_specs
    )


def hierarchical_psum(x: jax.Array, axis_names: Sequence[str]) -> jax.Array:
    """psum over mesh axes in order (innermost/thickest link first).

    One psum per axis keeps each collective on its own ICI ring instead of a
    single global ring whose latency is set by the thinnest (cross-pod) hop.
    """
    for ax in axis_names:
        x = lax.psum(x, ax)
    return x


def local_mma_then_psum(
    x: jax.Array,
    axis_names: Sequence[str],
    *,
    m: int = DEFAULT_M,
    backend: Optional[str] = None,
) -> jax.Array:
    """Full scalar reduction of a sharded array: the reduction engine on the
    local shard, then the mesh-axis rungs. This is eq. (13) spanning the
    whole machine. ``backend=None`` defers to the engine's process-wide
    default (``--reduce-backend`` / $REPRO_REDUCE_BACKEND / planner)."""
    # local import: repro.core's package init imports this module, while the
    # engine imports repro.core submodules -- deferring breaks the cycle.
    from repro import reduce as R

    local = R.reduce(x, kind="sum", backend=backend, m=m)
    return hierarchical_psum(local, axis_names)


# ------------------- deterministic fixed-order combine ----------------------


def axis_size_of(axis_name: str) -> int:
    """Static size of a bound mesh axis (a Python int inside shard_map)."""
    try:
        return lax.axis_size(axis_name)
    except AttributeError:  # pragma: no cover - jax<0.5: psum of a literal
        return lax.psum(1, axis_name)


def mesh_world_size(axis_names: Sequence[str]) -> int:
    """Product of the bound sizes of the given mesh axes."""
    world = 1
    for ax in axis_names:
        world *= int(axis_size_of(ax))
    return world


def fixed_order_combine(x: jax.Array, axis_names: Sequence[str]) -> jax.Array:
    """Deterministic cross-device sum: all-gather the per-device partials,
    then fold them in static device order (rank 0 first) — the PR 3
    lane-combine lifted one level up, per eq. (13)'s recurrence.

    Unlike ``lax.psum`` (whose reduction order is an implementation detail of
    the collective), every device runs the identical left fold over the
    identical gathered array, so the result is BIT-identical on every replica
    at any device count. Axes combine one at a time, innermost first, so each
    gather stays on its own mesh ring (thick-pipe-first, like
    ``hierarchical_psum``).
    """
    for ax in axis_names:
        g = lax.all_gather(x, ax, axis=0, tiled=False)
        p = g.shape[0]  # static: all_gather's gathered dim is the axis size
        acc = g[0]
        for i in range(1, p):
            acc = acc + g[i]
        x = acc
    return x


def _as_uint_bits(x: jax.Array) -> jax.Array:
    """Reinterpret floats as same-width unsigned ints so equality compares
    bit patterns (NaN-safe: NaN != NaN as floats, but its bits are its bits).
    """
    if jnp.issubdtype(x.dtype, jnp.floating):
        width = jnp.dtype(x.dtype).itemsize * 8
        return lax.bitcast_convert_type(x, jnp.dtype(f"uint{width}"))
    return x


def replica_bits_agree(x: jax.Array, axis_names: Sequence[str]) -> jax.Array:
    """Replicated scalar bool: True iff ``x``'s BIT pattern is identical on
    every device along the given axes (floats compared as raw bits, so NaN
    payloads and last-ulp drift both count as disagreement). Because every
    device gathers and compares the same set, the verdict itself is
    replica-invariant — a guard can fold it into the skip decision without
    introducing divergence of its own."""
    bits = _as_uint_bits(x)
    agree = jnp.bool_(True)
    for ax in axis_names:
        g = lax.all_gather(bits, ax, axis=0, tiled=False)
        agree = agree & jnp.all(g == g[0])
    return agree


def census_agreement(
    row: jax.Array, axis_names: Sequence[str]
) -> tuple[jax.Array, jax.Array]:
    """Combine an additive census/statistic row deterministically AND verify
    every replica arrived at the same bits.

    Returns ``(combined, agree)``: ``combined`` is
    ``fixed_order_combine(row, axis_names)``; ``agree`` is
    ``replica_bits_agree(combined, axis_names)`` — True everywhere unless a
    replica's fold desynced (different shard contents, a nondeterministic
    wire reduction), in which case it flips to False on EVERY device.
    """
    combined = fixed_order_combine(row, axis_names)
    return combined, replica_bits_agree(combined, axis_names)


# ----------------------------- ring all-reduce ------------------------------


def ring_all_reduce(x: jax.Array, axis_name: str) -> jax.Array:
    """Bucketed ring all-reduce built from ppermute: reduce-scatter pass then
    all-gather pass, 2(P-1) hops, each hop moving |x|/P bytes.

    Written explicitly (rather than lax.psum) so the scheduler can overlap
    the per-hop sends with unrelated compute, and so the compressed variant
    below can quantize the wire format per hop.
    """
    try:
        p = lax.axis_size(axis_name)
    except AttributeError:  # pragma: no cover - jax<0.5: psum of a literal
        p = lax.psum(1, axis_name)
    if p == 1:
        return x
    idx = lax.axis_index(axis_name)
    flat = x.reshape(-1)
    pad = (-flat.size) % p
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(p, -1)
    perm = [(i, (i + 1) % p) for i in range(p)]

    def rs_step(t, chunks):
        # each rank accumulates into chunk (idx - t - 1) which it just received
        send_ix = (idx - t) % p
        recv_ix = (idx - t - 1) % p
        sent = lax.ppermute(chunks[send_ix], axis_name, perm)
        return chunks.at[recv_ix].add(sent)

    chunks = lax.fori_loop(0, p - 1, rs_step, chunks)

    def ag_step(t, chunks):
        send_ix = (idx - t + 1) % p
        recv_ix = (idx - t) % p
        sent = lax.ppermute(chunks[send_ix], axis_name, perm)
        return chunks.at[recv_ix].set(sent)

    chunks = lax.fori_loop(0, p - 1, ag_step, chunks)
    out = chunks.reshape(-1)
    if pad:
        out = out[: out.size - pad]
    return out.reshape(x.shape)


# ----------------------- compressed (int8 EF) psum ---------------------------


def compressed_psum(
    x: jax.Array, axis_name: str, err: jax.Array | None = None
) -> tuple[jax.Array, jax.Array]:
    """int8 error-feedback all-reduce for the thin cross-pod hop.

    Protocol: add carried error, agree on a shared scale via pmax, quantize
    to int8, psum in int32 (exact), dequantize. The local quantization
    residual is returned as the next step's error carry (EF-SGD; convergence
    preserved under standard assumptions). Wire bytes: 1/4 of f32, 1/2 of
    bf16 -- targeted at the `pod` axis whose link is the bottleneck.

    Returns (allreduced_f32, new_error_carry).
    """
    xf = x.astype(jnp.float32)
    if err is not None:
        xf = xf + err
    amax = lax.pmax(jnp.max(jnp.abs(xf)), axis_name)
    scale = jnp.maximum(amax / 127.0, 1e-30)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    new_err = xf - q.astype(jnp.float32) * scale
    qsum = lax.psum(q.astype(jnp.int32), axis_name)
    return qsum.astype(jnp.float32) * scale, new_err


def hierarchical_grad_reduce(
    grad: jax.Array,
    *,
    dense_axes: Sequence[str] = ("data",),
    compressed_axis: str | None = "pod",
    err: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array | None]:
    """Gradient all-reduce: exact psum on intra-pod axes, optional int8-EF on
    the cross-pod axis. Mean-normalization is left to the caller (it knows
    the global data-parallel degree)."""
    g = grad
    for ax in dense_axes:
        g = lax.psum(g, ax)
    if compressed_axis is not None:
        g, err = compressed_psum(g, compressed_axis, err)
    return g, err


def make_sharded_global_norm_sq(
    mesh: jax.sharding.Mesh,
    *,
    backend: Optional[str] = None,
    deterministic: bool = False,
):
    """Global sum-of-squares of a sharded pytree: per-shard reduction through
    the engine (``reduce_tree``'s last-axis MMA path keeps every dot on the
    local shard), then the mesh rungs -- the optimizer's clipping statistic
    at scale. ``deterministic=True`` routes the cross-device rung through
    the engine's ``mesh_axes=`` path (fixed-order combine) instead of
    ``psum``: bit-identical on every replica at any device count."""
    axis_names = tuple(mesh.axis_names)

    def body(tree):
        from repro import reduce as R  # deferred: see local_mma_then_psum

        if deterministic:
            return R.reduce_tree(
                tree, kind="sumsq", backend=backend, mesh_axes=axis_names
            )
        local = R.reduce_tree(tree, kind="sumsq", backend=backend)
        return hierarchical_psum(local, axis_names)

    return functools.partial(
        # the deterministic path may launch a per-device Pallas kernel,
        # which has no shard_map replication rule -- checker off there
        shard_map_unchecked if deterministic else shard_map,
        body,
        mesh=mesh,
        in_specs=None,  # caller supplies per-leaf specs
        out_specs=jax.sharding.PartitionSpec(),
    )
