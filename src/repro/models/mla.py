"""Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3-4B).

Q and KV are projected through low-rank latents; the KV cache stores only the
compressed latent ``c_kv`` (+ the shared RoPE key), which is MLA's memory
contribution. Decode re-expands K/V from the latent per step (the "weight
absorption" algebraic fusion is a further TPU optimization noted in
EXPERIMENTS.md; it does not change the contract).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models import params as P


def mla_init(key, cfg):
    m = cfg.mla
    d = cfg.d_model
    h = cfg.n_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    ks = P.split(key, 7)
    pq_d, aq_d = P.dense_init(ks[0], d, m.q_lora_rank, ("embed", None), cfg_dtype(cfg))
    pq_u, aq_u = P.dense_init(ks[1], m.q_lora_rank, h * qk, (None, "heads"), cfg_dtype(cfg))
    pkv_d, akv_d = P.dense_init(
        ks[2], d, m.kv_lora_rank + m.qk_rope_dim, ("embed", None), cfg_dtype(cfg)
    )
    pkv_u, akv_u = P.dense_init(
        ks[3], m.kv_lora_rank, h * (m.qk_nope_dim + m.v_head_dim), (None, "heads"), cfg_dtype(cfg)
    )
    po, ao = P.dense_init(ks[4], h * m.v_head_dim, d, ("heads", "embed"), cfg_dtype(cfg))
    qn, aqn = P.norm_init("rmsnorm", m.q_lora_rank, cfg_dtype(cfg))
    kvn, akvn = P.norm_init("rmsnorm", m.kv_lora_rank, cfg_dtype(cfg))
    return (
        {"q_down": pq_d, "q_up": pq_u, "kv_down": pkv_d, "kv_up": pkv_u,
         "o": po, "q_norm": qn, "kv_norm": kvn},
        {"q_down": aq_d, "q_up": aq_u, "kv_down": akv_d, "kv_up": akv_u,
         "o": ao, "q_norm": aqn, "kv_norm": akvn},
    )


def cfg_dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _expand(p, x, positions, cfg):
    """Project x to per-head q, k, v (rope applied). Returns (q, k, v)."""
    m = cfg.mla
    h = cfg.n_heads
    b, s, _ = x.shape
    # Both latent norms are independent functions of x, so their statistics
    # batch into one segmented reduction pass (reduce_many; see
    # layers.rmsnorm_apply_many) -- one launch per layer instead of two.
    cq = P.dense_apply(p["q_down"], x)
    ckv_full = P.dense_apply(p["kv_down"], x)
    ckv_raw, k_rope = ckv_full[..., : m.kv_lora_rank], ckv_full[..., m.kv_lora_rank:]
    cq, ckv = L.rmsnorm_apply_many(
        (p["q_norm"], p["kv_norm"]),
        (cq, ckv_raw),
        eps=cfg.norm_eps,
        mma=cfg.mma_reductions,
    )
    q = P.dense_apply(p["q_up"], cq).reshape(b, s, h, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = L.rope(q_rope, positions, cfg.rope_theta)

    k_rope = L.rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # shared head
    kv = P.dense_apply(p["kv_up"], ckv).reshape(b, s, h, m.qk_nope_dim + m.v_head_dim)
    k_nope, v = kv[..., : m.qk_nope_dim], kv[..., m.qk_nope_dim:]
    k_rope_b = jnp.broadcast_to(k_rope, (b, s, h, m.qk_rope_dim))
    q_full = jnp.concatenate([q_nope, q_rope], -1)
    k_full = jnp.concatenate([k_nope, k_rope_b], -1)
    return q_full, k_full, v, ckv_full


def mla_train(p, x, positions, cfg):
    m = cfg.mla
    q, k, v, _ = _expand(p, x, positions, cfg)
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    out = A.flash_attention_xla(
        q, k, v, causal=True, mma=cfg.mma_reductions, sm_scale=scale
    )
    b, s, _, _ = out.shape
    return P.dense_apply(p["o"], out.reshape(b, s, -1))


def make_mla_cache(batch: int, s_max: int, cfg):
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, s_max, m.kv_lora_rank + m.qk_rope_dim), cfg_dtype(cfg)),
        "slot_pos": jnp.full((s_max,), -1, jnp.int32),
    }


def mla_fill_cache(p, x, positions, cache, cfg):
    """Prefill the compressed-latent cache. RoPE on the shared key is applied
    at *write* time (positions are absolute)."""
    m = cfg.mla
    ckv_full = P.dense_apply(p["kv_down"], x)
    k_rope = L.rope(
        ckv_full[..., m.kv_lora_rank:][:, :, None, :], positions, cfg.rope_theta
    )[:, :, 0, :]
    stored = jnp.concatenate([ckv_full[..., : m.kv_lora_rank], k_rope], -1)
    s = x.shape[1]
    ckv = jax.lax.dynamic_update_slice(cache["ckv"], stored, (0, 0, 0))
    slot_pos = cache["slot_pos"].at[:s].set(jnp.arange(s))
    return {"ckv": ckv, "slot_pos": slot_pos}


def mla_decode(p, x_t, cache, pos, cfg):
    """One decode step from the compressed cache, *weight-absorbed*.

    Production MLA serving never expands per-head K/V over the cache (that
    materializes a (B, S, H, d) tensor per layer per step -- caught by the
    dry-run at 29 GB/device temp on decode_32k). Instead the up-projections
    are folded into the query and output:

      score_h(i) = (W_uk_h^T q_nope_h) . c_i + q_rope_h . k_rope_i
      out_h      = W_uv_h^T (sum_i p_h(i) c_i)

    so attention runs entirely in the R-dim latent space; per-step memory is
    O(B*S*R) reads + O(B*H*R) temporaries.
    """
    m = cfg.mla
    h = cfg.n_heads
    b = x_t.shape[0]
    posb = jnp.broadcast_to(pos, (b, 1))
    # query
    cq = P.dense_apply(p["q_down"], x_t)
    cq = L.norm_apply("rmsnorm", p["q_norm"], cq, eps=cfg.norm_eps, mma=cfg.mma_reductions)
    q = P.dense_apply(p["q_up"], cq).reshape(b, 1, h, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = L.rope(q_rope, posb, cfg.rope_theta)[:, 0]        # (B, H, dr)
    # write this step's latent
    ckv_full = P.dense_apply(p["kv_down"], x_t)
    k_rope_t = L.rope(
        ckv_full[..., m.kv_lora_rank:][:, :, None, :], posb, cfg.rope_theta
    )[:, :, 0, :]
    stored = jnp.concatenate([ckv_full[..., : m.kv_lora_rank], k_rope_t], -1)
    ckv_cache = jax.lax.dynamic_update_slice(cache["ckv"], stored, (0, pos, 0))
    slot_pos = jax.lax.dynamic_update_slice(
        cache["slot_pos"], pos[None].astype(jnp.int32), (pos,)
    )
    # normalized latents + shared rope key, straight from the cache
    c_all = L.norm_apply(
        "rmsnorm", p["kv_norm"], ckv_cache[..., : m.kv_lora_rank],
        eps=cfg.norm_eps, mma=cfg.mma_reductions,
    )                                                           # (B, S, R)
    k_rope_all = ckv_cache[..., m.kv_lora_rank:]                # (B, S, dr)
    # absorb W_uk into the query: q_c[b,h,r] = sum_d q_nope[b,h,d] Wuk[r,h,d]
    wkv = p["kv_up"]["w"].reshape(m.kv_lora_rank, h, m.qk_nope_dim + m.v_head_dim)
    w_uk, w_uv = wkv[..., : m.qk_nope_dim], wkv[..., m.qk_nope_dim:]
    # match the bf16 MXU convention of every other attention path (the
    # train-side flash attention computes scores/PV in bf16 too)
    cd = jnp.bfloat16
    q_c = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32),
                     w_uk.astype(jnp.float32))
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    s = (
        jnp.einsum("bhr,bsr->bhs", q_c.astype(cd), c_all.astype(cd),
                   preferred_element_type=jnp.float32)
        + jnp.einsum("bhd,bsd->bhs", q_rope.astype(cd), k_rope_all.astype(cd),
                     preferred_element_type=jnp.float32)
    ) * scale
    valid = (slot_pos >= 0) & (slot_pos <= pos)
    s = jnp.where(valid[None, None], s, -1e30)
    mx = jnp.max(s, -1, keepdims=True)
    e = jnp.where(valid[None, None], jnp.exp(s - mx), 0.0)
    from repro import reduce as R

    denom = R.reduce(e, axis=-1, backend=R.backend_for_flags(cfg.mma_reductions))
    p_attn = e / jnp.maximum(denom, 1e-30)[..., None]           # (B, H, S)
    o_lat = jnp.einsum("bhs,bsr->bhr", p_attn.astype(cd), c_all.astype(cd),
                       preferred_element_type=jnp.float32)      # (B, H, R)
    out_h = jnp.einsum("bhr,rhd->bhd", o_lat, w_uv.astype(jnp.float32))
    out = P.dense_apply(p["o"], out_h.reshape(b, 1, -1).astype(x_t.dtype))
    return out, {"ckv": ckv_cache, "slot_pos": slot_pos}
