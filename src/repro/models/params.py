"""Parameter initialization with logical sharding axes.

No flax/haiku: params are nested dicts of jnp arrays, and every init helper
returns a parallel ``axes`` tree whose leaves are tuples of *logical* axis
names (or None). `repro.launch.sharding` maps logical names onto mesh axes
("data", "model", "pod"), which is how one model definition serves the
single-pod and multi-pod production meshes unchanged.

Logical axis vocabulary:
  "vocab"    embedding rows / logit columns
  "embed"    the d_model dimension (FSDP-sharded for storage)
  "ffn"      MLP hidden dimension (tensor-parallel)
  "heads"    fused attention head dim: n_heads * d_head (tensor-parallel)
  "kv_heads" fused KV head dim
  "experts"  MoE expert dimension (expert-parallel)
  "inner"    SSM / RG-LRU inner width (tensor-parallel)
  None       replicated
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, in_dim: int, out_dim: int, axes, dtype, scale=None):
    """(in, out) weight; axes is the logical-axes tuple for the weight."""
    if scale is None:
        scale = in_dim**-0.5
    w = jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale
    return {"w": w.astype(dtype)}, {"w": axes}


def dense_apply(params, x):
    return x @ params["w"].astype(x.dtype)


def padded_vocab(vocab: int, multiple: int = 256) -> int:
    """Megatron-style vocab padding so the vocab dim shards over any model
    degree <= `multiple` (e.g. mamba2's 50280 -> 50432). Pad logits are
    masked to -inf in model._head; pad rows are never indexed."""
    return -(-vocab // multiple) * multiple


def embed_init(key, vocab: int, dim: int, dtype):
    nv = padded_vocab(vocab)
    tbl = jax.random.normal(key, (nv, dim), jnp.float32) * (dim**-0.5)
    # NOTE: the table's d_model dim is deliberately NOT FSDP-sharded: the
    # embedding/head is already vocab-sharded, and d-sharding it makes the
    # CE head gather the full table per loss chunk (caught by the dry-run's
    # collective analysis -- see EXPERIMENTS.md Perf iteration 1).
    return {"table": tbl.astype(dtype)}, {"table": ("vocab", None)}


def norm_init(kind: str, dim: int, dtype):
    if kind == "rmsnorm":
        return {"scale": jnp.ones((dim,), dtype)}, {"scale": ("embed",)}
    if kind == "layernorm":
        return (
            {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)},
            {"scale": ("embed",), "bias": ("embed",)},
        )
    if kind == "layernorm_np":  # OLMo: non-parametric
        return {}, {}
    raise ValueError(kind)


def split(key, n: int):
    return list(jax.random.split(key, n))


def stack_init(init_fn, key, n: int):
    """Initialize ``n`` copies of a module stacked on a leading axis (for
    lax.scan over layer units). ``init_fn(key) -> (params, axes)``; the
    stacked axes leaves get a leading None (layer axis is never sharded)."""
    keys = jnp.stack(jax.random.split(key, n))
    params = jax.vmap(lambda k: init_fn(k)[0])(keys)
    _, axes = init_fn(keys[0])  # axes tree only (params discarded)
    axes = jax.tree.map(
        lambda a: (None,) + tuple(a) if a else None,
        axes,
        is_leaf=lambda a: a is None or (isinstance(a, tuple) and all(isinstance(s, (str, type(None))) for s in a)),
    )
    return params, axes


def count_params(tree) -> int:
    return sum(x.size for x in jax.tree.leaves(tree))
