"""Model zoo: pattern-cycled decoder stacks covering all assigned families.

model.init_params / forward / prefill / decode_step / make_caches are the
public contract used by the launcher, the dry-run and the examples.
"""

from repro.models.model import (  # noqa: F401
    decode_step,
    forward,
    init_params,
    make_caches,
    prefill,
)
from repro.models import losses  # noqa: F401
