"""RG-LRU recurrent block (Griffin / RecurrentGemma). [arXiv:2402.19427]

Temporal mixing: two branches from the residual stream --
  gate branch:      linear(d -> w) -> GeLU
  recurrent branch: linear(d -> w) -> causal conv1d -> RG-LRU
merged by elementwise product, then linear(w -> d).

RG-LRU recurrence (per channel):
  r_t = sigmoid(block_diag_linear_a(u_t))      recurrence gate
  i_t = sigmoid(block_diag_linear_x(u_t))      input gate
  a_t = exp(-c * softplus(Lambda) * r_t)
  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Paper-applicability note (DESIGN.md): the recurrence hop h_t = a h + b is a
first-order *non-uniform* scan -- it has no all-ones-MMA encoding, so it runs
as jax.lax.associative_scan (log-depth, VPU). The block's surrounding
reductions (norms, gates) still ride the MMA path. Gate projections are
block-diagonal per Griffin (16 blocks), keeping params O(w^2 / 16).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import params as P

N_GATE_BLOCKS = 16


def _width(cfg):
    return (cfg.rglru.lru_width or cfg.d_model) if cfg.rglru else cfg.d_model


def rglru_init(key, cfg):
    w = _width(cfg)
    d = cfg.d_model
    r = cfg.rglru
    dt = jnp.dtype(cfg.dtype)
    ks = P.split(key, 6)
    px, apx = P.dense_init(ks[0], d, w, ("embed", "inner"), dt)
    pg, apg = P.dense_init(ks[1], d, w, ("embed", "inner"), dt)
    po, apo = P.dense_init(ks[2], w, d, ("inner", "embed"), dt)
    nb = N_GATE_BLOCKS
    bs = w // nb
    ga = (jax.random.normal(ks[3], (nb, bs, bs), jnp.float32) * bs**-0.5).astype(dt)
    gx = (jax.random.normal(ks[4], (nb, bs, bs), jnp.float32) * bs**-0.5).astype(dt)
    # Lambda init so a^(1/r) spans ~[0.9, 0.999] (Griffin appendix)
    u = jax.random.uniform(ks[5], (w,), jnp.float32, 0.9**2, 0.999**2)
    lam = jnp.log(jnp.exp(-jnp.log(u) / (2 * r.c)) - 1.0)  # softplus^-1
    params = {
        "in_x": px, "in_gate": pg, "out": po,
        "conv_w": (jax.random.normal(key, (r.conv_width, w), jnp.float32)
                   * r.conv_width**-0.5).astype(dt),
        "gate_a": ga, "gate_x": gx,
        "lam": lam,
    }
    axes = {
        "in_x": apx, "in_gate": apg, "out": apo,
        "conv_w": (None, "inner"),
        "gate_a": ("inner", None, None), "gate_x": ("inner", None, None),
        "lam": ("inner",),
    }
    return params, axes


def _block_diag(u, wblk):
    """u: (..., w); wblk: (nb, bs, bs) -> (..., w)."""
    nb, bs, _ = wblk.shape
    ub = u.reshape(u.shape[:-1] + (nb, bs))
    out = jnp.einsum("...nb,nbc->...nc", ub, wblk.astype(u.dtype))
    return out.reshape(u.shape)


def _gates(p, u, cfg):
    c = cfg.rglru.c
    r = jax.nn.sigmoid(_block_diag(u, p["gate_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid(_block_diag(u, p["gate_x"]).astype(jnp.float32))
    log_a = -c * jax.nn.softplus(p["lam"]) * r          # (..., w), negative
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2 * log_a), 1e-12)) * (
        i * u.astype(jnp.float32)
    )
    return a, b


def rglru_train(p, x, cfg, return_state: bool = False):
    """(B, L, d) -> (B, L, d). Recurrence via associative scan over L.
    With return_state, also returns the decode cache (conv tail + h_T)."""
    u_raw = P.dense_apply(p["in_x"], x)
    u = L.causal_conv1d(u_raw, p["conv_w"])
    a, b = _gates(p, u, cfg)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    gate = jax.nn.gelu(P.dense_apply(p["in_gate"], x).astype(jnp.float32))
    y = (h * gate).astype(x.dtype)
    out = P.dense_apply(p["out"], y)
    if not return_state:
        return out
    k = cfg.rglru.conv_width
    l = x.shape[1]
    pad = max(0, (k - 1) - l)
    tail = jnp.pad(u_raw, ((0, 0), (pad, 0), (0, 0)))[:, -(k - 1):]
    return out, {"conv": tail, "h": h[:, -1]}


def make_rglru_cache(batch: int, cfg):
    w = _width(cfg)
    r = cfg.rglru
    return {
        "conv": jnp.zeros((batch, r.conv_width - 1, w), jnp.dtype(cfg.dtype)),
        "h": jnp.zeros((batch, w), jnp.float32),
    }


def rglru_decode(p, x_t, cache, cfg):
    """One decode step. x_t: (B, 1, d). O(1) recurrent state."""
    xt = x_t[:, 0]
    u_t = P.dense_apply(p["in_x"], xt)
    conv_state, u_t = L.conv1d_step(cache["conv"], u_t, p["conv_w"])
    a, b = _gates(p, u_t, cfg)
    h = a * cache["h"] + b
    gate = jax.nn.gelu(P.dense_apply(p["in_gate"], xt).astype(jnp.float32))
    y = (h * gate).astype(x_t.dtype)
    out = P.dense_apply(p["out"], y)[:, None, :]
    return out, {"conv": conv_state, "h": h}
