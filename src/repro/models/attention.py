"""Attention: chunked flash-style (XLA), decode caches, GQA/MQA/local/cross.

Three execution paths, one contract (oracle: kernels.flash_attention.ref):
  * train/prefill: `flash_attention_xla` -- q and kv are tiled by lax.scan
    with an online softmax, O(Sq * kv_chunk) score memory. This is the path
    the multi-pod dry-run lowers (XLA:TPU fuses it; sub-quadratic memory is
    what makes prefill_32k compile within HBM).
  * TPU kernel: cfg.use_pallas routes to kernels.flash_attention (Pallas).
  * decode: cache-resident single-token attention; full cache for global
    attention, *ring buffer* cache for local (windowed) attention so
    long_500k holds O(window) state, not O(S).

Softmax denominators ride the MXU via `layers.softmax_mma` / the MMA row-sum
inside the online update (the paper's eq. 9) when cfg.mma_reductions is on.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import reduce as R
from repro.models import layers as L
from repro.models import params as P

NEG = -1e30


# ------------------------------ projections ---------------------------------


def attn_init(key, d: int, n_heads: int, n_kv: int, d_head: int, dtype):
    ks = P.split(key, 4)
    q, aq = P.dense_init(ks[0], d, n_heads * d_head, ("embed", "heads"), dtype)
    k, ak = P.dense_init(ks[1], d, n_kv * d_head, ("embed", "kv_heads"), dtype)
    v, av = P.dense_init(ks[2], d, n_kv * d_head, ("embed", "kv_heads"), dtype)
    o, ao = P.dense_init(
        ks[3], n_heads * d_head, d, ("heads", "embed"), dtype, scale=(n_heads * d_head) ** -0.5
    )
    return {"q": q, "k": k, "v": v, "o": o}, {"q": aq, "k": ak, "v": av, "o": ao}


def _project_qkv(p, x, n_heads, n_kv, d_head):
    b, s, _ = x.shape
    q = P.dense_apply(p["q"], x).reshape(b, s, n_heads, d_head)
    k = P.dense_apply(p["k"], x).reshape(b, s, n_kv, d_head)
    v = P.dense_apply(p["v"], x).reshape(b, s, n_kv, d_head)
    return q, k, v


# ------------------------- chunked flash attention --------------------------


def _online_block(carry, qc, kc, vc, qpos, kpos, *, causal, window, kv_len, scale, mma):
    """One (q-chunk, kv-chunk) online-softmax update.

    qc: (B, Cq, Hkv, G, D); kc/vc: (B, Ck, Hkv, D).
    carry m/l: (B, Hkv, G, Cq); acc: (B, Hkv, G, Cq, D).
    """
    m, l, acc = carry
    s = jnp.einsum(
        "bqhgd,bkhd->bhgqk",
        qc.astype(jnp.bfloat16),
        kc.astype(jnp.bfloat16),
        preferred_element_type=jnp.float32,
    ) * scale
    mask = kpos[None, :] < kv_len
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= (qpos[:, None] - kpos[None, :]) < window
    s = jnp.where(mask[None, None, None], s, NEG)
    m_new = jnp.maximum(m, jnp.max(s, -1))
    e = jnp.exp(s - m_new[..., None])
    e = jnp.where(mask[None, None, None], e, 0.0)
    esum = R.reduce(e, axis=-1, backend=R.backend_for_flags(mma))
    alpha = jnp.exp(m - m_new)
    l_new = l * alpha + esum
    pv = jnp.einsum(
        "bhgqk,bkhd->bhgqd",
        e.astype(jnp.bfloat16),
        vc.astype(jnp.bfloat16),
        preferred_element_type=jnp.float32,
    )
    acc_new = acc * alpha[..., None] + pv
    return m_new, l_new, acc_new


def flash_attention_xla(
    q: jax.Array,   # (B, Sq, H, D)
    k: jax.Array,   # (B, Skv, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    mma: bool = True,
    sm_scale: float | None = None,
) -> jax.Array:
    b, sq, h, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]  # MLA: value head dim may differ from qk head dim
    g = h // hkv
    scale = sm_scale if sm_scale is not None else d**-0.5
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    nq = -(-sq // q_chunk)
    nk = -(-skv // kv_chunk)
    sq_p, skv_p = nq * q_chunk, nk * kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
    qg = qp.reshape(b, nq, q_chunk, hkv, g, d).swapaxes(0, 1)  # (nq, B, Cq, Hkv, G, D)
    kg = kp.reshape(b, nk, kv_chunk, hkv, d).swapaxes(0, 1)
    vg = vp.reshape(b, nk, kv_chunk, hkv, dv).swapaxes(0, 1)

    def per_q_chunk(_, qin):
        qc, iq = qin
        qpos = q_offset + iq * q_chunk + jnp.arange(q_chunk)

        # remat per KV chunk: the backward pass recomputes s/e tiles instead
        # of saving the O(S x S) score tensors (flash-attention's recompute
        # contract -- without this, bwd residuals are the full quadratic
        # attention matrix per layer; caught by dry-run memory_analysis).
        @jax.checkpoint
        def per_kv_chunk(carry, kin):
            kc, vc, ik = kin
            kpos = ik * kv_chunk + jnp.arange(kv_chunk)
            return (
                _online_block(
                    carry, qc, kc, vc, qpos, kpos,
                    causal=causal, window=window, kv_len=skv, scale=scale, mma=mma,
                ),
                None,
            )

        init = (
            jnp.full((b, hkv, g, q_chunk), NEG, jnp.float32),
            jnp.zeros((b, hkv, g, q_chunk), jnp.float32),
            jnp.zeros((b, hkv, g, q_chunk, dv), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(
            per_kv_chunk, init, (kg, vg, jnp.arange(nk))
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]          # (B,Hkv,G,Cq,Dv)
        return None, out.transpose(0, 3, 1, 2, 4)             # (B,Cq,Hkv,G,Dv)

    _, outs = jax.lax.scan(per_q_chunk, None, (qg, jnp.arange(nq)))
    out = outs.swapaxes(0, 1).reshape(b, sq_p, h, dv)[:, :sq]
    return out.astype(q.dtype)


# ------------------------------- decode -------------------------------------


def decode_attention(
    q: jax.Array,        # (B, 1, H, D) -- already RoPE'd
    k_cache: jax.Array,  # (B, Smax, Hkv, D) -- RoPE'd at write time
    v_cache: jax.Array,
    slot_pos: jax.Array,  # (Smax,) int32 absolute position per slot, -1 empty
    pos: jax.Array,       # scalar: current query position
    *,
    window: int | None = None,
    mma: bool = True,
    sm_scale: float | None = None,
) -> jax.Array:
    b, _, h, d = q.shape
    hkv = k_cache.shape[2]
    g = h // hkv
    scale = sm_scale if sm_scale is not None else d**-0.5
    qg = q.reshape(b, hkv, g, d)
    s = jnp.einsum(
        "bhgd,bshd->bhgs",
        qg.astype(jnp.bfloat16),
        k_cache.astype(jnp.bfloat16),
        preferred_element_type=jnp.float32,
    ) * scale
    valid = (slot_pos >= 0) & (slot_pos <= pos)
    if window is not None:
        valid &= (pos - slot_pos) < window
    s = jnp.where(valid[None, None, None], s, NEG)
    m = jnp.max(s, -1, keepdims=True)
    e = jnp.where(valid[None, None, None], jnp.exp(s - m), 0.0)
    denom = R.reduce(e, axis=-1, backend=R.backend_for_flags(mma))
    out = jnp.einsum(
        "bhgs,bshd->bhgd",
        e.astype(jnp.bfloat16),
        v_cache.astype(jnp.bfloat16),
        preferred_element_type=jnp.float32,
    ) / jnp.maximum(denom, 1e-30)[..., None]
    return out.reshape(b, 1, h, v_cache.shape[-1]).astype(q.dtype)


# --------------------------- full attention blocks ---------------------------


def self_attention_train(p, x, positions, cfg, *, window=None):
    """(B, S, d) -> (B, S, d). Causal self-attention, train/prefill path."""
    q, k, v = _project_qkv(p, x, cfg.n_heads, cfg.n_kv_heads, cfg.d_head)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    if cfg.use_pallas:
        from repro.kernels import flash_attention_diff

        out = flash_attention_diff(
            q.swapaxes(1, 2), k.swapaxes(1, 2), v.swapaxes(1, 2), True, window, 0, None
        ).swapaxes(1, 2)
    else:
        out = flash_attention_xla(
            q, k, v, causal=True, window=window, mma=cfg.mma_reductions
        )
    b, s, _, _ = out.shape
    return P.dense_apply(p["o"], out.reshape(b, s, -1))


def make_kv_cache(batch: int, s_max: int, n_kv: int, d_head: int, dtype):
    return {
        "k": jnp.zeros((batch, s_max, n_kv, d_head), dtype),
        "v": jnp.zeros((batch, s_max, n_kv, d_head), dtype),
        "slot_pos": jnp.full((s_max,), -1, jnp.int32),
    }


def self_attention_decode(p, x_t, cache, pos, cfg, *, window=None):
    """One decode step. x_t: (B, 1, d); cache: full or ring (ring iff window).
    Returns (out (B,1,d), new_cache)."""
    b = x_t.shape[0]
    q, k, v = _project_qkv(p, x_t, cfg.n_heads, cfg.n_kv_heads, cfg.d_head)
    posb = jnp.broadcast_to(pos, (b, 1))
    q = L.rope(q, posb, cfg.rope_theta)
    k = L.rope(k, posb, cfg.rope_theta)
    s_max = cache["k"].shape[1]
    # full cache: s_max > pos always so slot == pos; ring cache (local attn,
    # s_max == window): the slot rotates and evicts the oldest key.
    slot = pos % s_max
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    slot_pos = jax.lax.dynamic_update_slice(
        cache["slot_pos"], pos[None].astype(jnp.int32), (slot,)
    )
    out = decode_attention(
        q, k_cache, v_cache, slot_pos, pos, window=window, mma=cfg.mma_reductions
    )
    out = P.dense_apply(p["o"], out.reshape(b, 1, -1))
    return out, {"k": k_cache, "v": v_cache, "slot_pos": slot_pos}


def fill_kv_cache(p, x, positions, cache, cfg):
    """Prefill: project+rope the whole prompt into the cache (full caches;
    ring caches keep the last `window` positions)."""
    b, s, _ = x.shape
    _, k, v = _project_qkv(p, x, cfg.n_heads, cfg.n_kv_heads, cfg.d_head)
    k = L.rope(k, positions, cfg.rope_theta)
    s_max = cache["k"].shape[1]
    if s <= s_max:
        k_cache = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0))
        slot_pos = cache["slot_pos"].at[:s].set(jnp.arange(s))
    else:  # ring: keep the last s_max positions, each at slot pos % s_max so
        # later decode writes (slot = pos % s_max) evict oldest-first.
        tail = jnp.arange(s - s_max, s)
        perm = jnp.argsort(tail % s_max)  # perm[i] = tail index whose slot is i
        k_cache = k[:, -s_max:][:, perm]
        v_cache = v[:, -s_max:][:, perm]
        slot_pos = tail[perm].astype(jnp.int32)
    return {"k": k_cache, "v": v_cache, "slot_pos": slot_pos}


# ------------------------------ cross-attention ------------------------------


def cross_attention_init(key, d: int, n_heads: int, n_kv: int, d_head: int, dtype):
    p, a = attn_init(key, d, n_heads, n_kv, d_head, dtype)
    p["gate"] = jnp.zeros((), dtype)  # zero-init tanh gate (Llama-3.2-vision)
    a["gate"] = None
    return p, a


def cross_attention_apply(p, x, ctx, cfg):
    """x: (B, S, d) queries; ctx: (B, N, d) frontend embeddings (kv)."""
    b, s, _ = x.shape
    n = ctx.shape[1]
    q = P.dense_apply(p["q"], x).reshape(b, s, cfg.n_heads, cfg.d_head)
    k = P.dense_apply(p["k"], ctx).reshape(b, n, cfg.n_kv_heads, cfg.d_head)
    v = P.dense_apply(p["v"], ctx).reshape(b, n, cfg.n_kv_heads, cfg.d_head)
    out = flash_attention_xla(q, k, v, causal=False, mma=cfg.mma_reductions)
    out = P.dense_apply(p["o"], out.reshape(b, s, -1))
    return jnp.tanh(p["gate"].astype(jnp.float32)).astype(out.dtype) * out
