"""Shared layers: norms (MMA-statistics), FFNs, embeddings, RoPE.

Normalization statistics route through the unified reduction engine
(``repro.reduce``): with ``cfg.mma_reductions`` on the engine selects the
paper's MMA encoding -- in the compiled HLO the reduction appears as an
all-ones dot feeding the MXU instead of a `reduce`. With the flag off the
same layers use the "xla" backend; that pair is the paper-vs-baseline
comparison measured in EXPERIMENTS.md. On TPU with ``cfg.use_pallas`` the
fused Pallas kernels take over.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import reduce as R
from repro.models import params as P


# ------------------------------- norms --------------------------------------


def norm_apply(kind: str, p, x, *, eps: float, mma: bool, use_pallas: bool = False):
    if use_pallas:
        from repro import kernels as K

        if kind == "rmsnorm":
            return K.rmsnorm(x, p["scale"], eps)
        if kind == "layernorm_np":
            return K.layernorm_np(x, eps)
    # Statistics in f32 (via the MMA path), but the normalization APPLY in
    # the activation dtype: keeping the apply in f32 puts every residual-
    # stream cotangent inside an f32 window, which doubles the TP backward
    # all-reduce bytes (caught by the dry-run; Perf iteration 2b).
    xf = x.astype(jnp.float32)
    d = x.shape[-1]
    backend = R.backend_for_flags(mma)
    if kind == "rmsnorm":
        # mirrors the historical MMA path: bf16 multipliers, f32 accumulate
        ss = R.reduce(xf, axis=-1, kind="sumsq", backend=backend,
                      compute_dtype=None if not mma else "bfloat16")
        return _rmsnorm_from_sumsq(p, x, ss, d, eps)
    if kind in ("layernorm", "layernorm_np"):
        s, ss = R.reduce(xf, axis=-1, kind="moments", backend=backend)
        mu = s / d
        var = jnp.maximum(ss / d - mu * mu, 0.0)
        rstd = jax.lax.rsqrt(var + eps)
        y = (x - mu[..., None].astype(x.dtype)) * rstd[..., None].astype(x.dtype)
        if kind == "layernorm":
            y = y * p["scale"].astype(x.dtype) + p["bias"].astype(x.dtype)
        return y
    raise ValueError(kind)


def _rmsnorm_from_sumsq(p, x, ss, d: int, eps: float):
    rstd = jax.lax.rsqrt(ss / d + eps).astype(x.dtype)
    return x * rstd[..., None] * p["scale"].astype(x.dtype)


def rmsnorm_apply_many(ps, xs, *, eps: float, mma: bool):
    """Apply N *independent* RMSNorms with every statistic in ONE pass.

    The per-layer norm statistics are the highest-frequency small reductions
    in a step; when several norms sit at the same program point (e.g. MLA's
    q-latent and kv-latent norms), their sumsq rows batch into a single
    width-padded eq. (9) dot via ``repro.reduce.reduce_many(axis=-1)`` --
    one launch for the whole group instead of one per norm. Same numerics
    as N ``norm_apply("rmsnorm", ...)`` calls (zero-padding is exact under
    f32 accumulation). Returns the list of normalized tensors.
    """
    backend = R.backend_for_flags(mma)
    sss = R.reduce_many(
        [x.astype(jnp.float32) for x in xs],
        kind="sumsq",
        axis=-1,
        backend=backend,
        compute_dtype=None if not mma else "bfloat16",
    )
    return [
        _rmsnorm_from_sumsq(p, x, ss, x.shape[-1], eps)
        for p, x, ss in zip(ps, xs, sss)
    ]


def softmax_mma(s: jax.Array, *, mma: bool, axis: int = -1) -> jax.Array:
    """Softmax whose denominator reduction uses the MMA row-sum when enabled.
    Max-subtraction stays a VPU op (max has no '+' MMA encoding)."""
    sf = s.astype(jnp.float32)
    m = jnp.max(sf, axis=axis, keepdims=True)
    e = jnp.exp(sf - m)
    if mma and axis in (-1, s.ndim - 1):
        denom = R.reduce(e, axis=-1, backend=R.backend_for_flags(True))[..., None]
    else:
        denom = jnp.sum(e, axis=axis, keepdims=True)
    return (e / jnp.maximum(denom, 1e-30)).astype(s.dtype)


# -------------------------------- FFN ---------------------------------------


def ffn_init(key, d: int, d_ff: int, kind: str, dtype):
    ks = P.split(key, 3)
    if kind == "swiglu":
        gate, ag = P.dense_init(ks[0], d, d_ff, ("embed", "ffn"), dtype)
        up, au = P.dense_init(ks[1], d, d_ff, ("embed", "ffn"), dtype)
        down, ad = P.dense_init(ks[2], d_ff, d, ("ffn", "embed"), dtype)
        return (
            {"gate": gate, "up": up, "down": down},
            {"gate": ag, "up": au, "down": ad},
        )
    if kind == "gelu":
        up, au = P.dense_init(ks[0], d, d_ff, ("embed", "ffn"), dtype)
        down, ad = P.dense_init(ks[1], d_ff, d, ("ffn", "embed"), dtype)
        return {"up": up, "down": down}, {"up": au, "down": ad}
    raise ValueError(kind)


def ffn_apply(p, x, kind: str):
    if kind == "swiglu":
        h = jax.nn.silu(P.dense_apply(p["gate"], x)) * P.dense_apply(p["up"], x)
    else:
        h = jax.nn.gelu(P.dense_apply(p["up"], x))
    return P.dense_apply(p["down"], h)


# -------------------------------- RoPE --------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float, rot_dim: int | None = None):
    """Rotary embedding. x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    rot = rot_dim or d
    half = rot // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:rot]
    xr1 = x1 * cos - x2 * sin
    xr2 = x2 * cos + x1 * sin
    return jnp.concatenate([xr1, xr2, x[..., rot:]], -1).astype(x.dtype)


# ---------------------------- causal conv1d ----------------------------------


def causal_conv1d(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: (B, L, C); w: (K, C). Returns (B, L, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32),
        w.astype(jnp.float32)[:, None, :],  # (K, 1, C) KIO
        window_strides=(1,),
        padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=w.shape[1],
    )
    return out.astype(x.dtype)


def conv1d_step(conv_state: jax.Array, x_t: jax.Array, w: jax.Array):
    """One decode step of the causal conv. conv_state: (B, K-1, C) holds the
    previous K-1 inputs; x_t: (B, C). Returns (new_state, y_t)."""
    k = w.shape[0]
    window = jnp.concatenate([conv_state, x_t[:, None, :]], 1)  # (B, K, C)
    y = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w.astype(jnp.float32))
    return window[:, 1:], y.astype(x_t.dtype)
