"""Model assembly: pattern-cycled decoder stack with scan-over-units.

A config's ``block_pattern`` (e.g. ("rec","rec","attn") for RecurrentGemma,
("attn","attn","attn","attn","xattn") for Llama-3.2-Vision) is cycled to
n_layers. Layers are grouped into *units* of one pattern period; unit params
are stacked on a leading axis and the stack is driven by ``lax.scan`` so the
HLO -- and the 512-device dry-run compile time -- stays flat in depth. A
partial tail unit (e.g. RecurrentGemma's 38 = 12*3 + 2) is applied unrolled.

Three entry points with one parameter tree:
  forward      (B, S) tokens -> logits           train / teacher-forcing
  prefill      builds every block's cache        inference phase 1
  decode_step  one token with caches             inference phase 2

Caches are per-kind pytrees (full KV, ring KV for local attention, compressed
latent for MLA, O(1) conv+state for SSM / RG-LRU) stacked exactly like the
params so the same scan drives them.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import context as CTX
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import params as P
from repro.models import rglru as REC
from repro.models import ssm as SSM


# ------------------------------- blocks -------------------------------------


def _has_ffn(kind: str) -> bool:
    return kind in ("attn", "local_attn", "xattn", "rec")


def _ffn_init(key, cfg):
    if cfg.moe is not None:
        return MOE.moe_init(key, cfg)
    return L.ffn_init(key, cfg.d_model, cfg.d_ff, cfg.ffn_kind, jnp.dtype(cfg.dtype))


def _ffn_apply(p, h, cfg):
    if cfg.moe is not None:
        return MOE.moe_apply(p, h, cfg)
    return L.ffn_apply(p, h, cfg.ffn_kind), {}


def block_init(kind: str, key, cfg: ModelConfig):
    ks = P.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    p, a = {}, {}
    p["norm1"], a["norm1"] = P.norm_init(cfg.norm, d, dt)
    if kind in ("attn", "local_attn"):
        if cfg.mla is not None:
            p["mix"], a["mix"] = MLA.mla_init(ks[0], cfg)
        else:
            p["mix"], a["mix"] = A.attn_init(
                ks[0], d, cfg.n_heads, cfg.n_kv_heads, cfg.d_head, dt
            )
    elif kind == "xattn":
        p["mix"], a["mix"] = A.cross_attention_init(
            ks[0], d, cfg.n_heads, cfg.n_kv_heads, cfg.d_head, dt
        )
    elif kind == "ssm":
        p["mix"], a["mix"] = SSM.ssm_init(ks[0], cfg)
    elif kind == "rec":
        p["mix"], a["mix"] = REC.rglru_init(ks[0], cfg)
    else:
        raise ValueError(kind)
    if _has_ffn(kind):
        p["norm2"], a["norm2"] = P.norm_init(cfg.norm, d, dt)
        p["ffn"], a["ffn"] = _ffn_init(ks[1], cfg)
    return p, a


def _norm(p, h, cfg):
    return L.norm_apply(
        cfg.norm, p, h, eps=cfg.norm_eps, mma=cfg.mma_reductions,
        use_pallas=cfg.use_pallas,
    )


def block_train(kind, p, h, positions, cfg, ctx):
    """One block, train/prefill compute. Returns (h, aux_loss_scalar)."""
    hn = _norm(p["norm1"], h, cfg)
    if kind in ("attn", "local_attn"):
        win = cfg.window if kind == "local_attn" else None
        if cfg.mla is not None:
            mix = MLA.mla_train(p["mix"], hn, positions, cfg)
        else:
            mix = A.self_attention_train(p["mix"], hn, positions, cfg, window=win)
    elif kind == "xattn":
        mix = A.cross_attention_apply(p["mix"], hn, ctx, cfg)
    elif kind == "ssm":
        mix = SSM.ssm_train(p["mix"], hn, cfg)
    elif kind == "rec":
        mix = REC.rglru_train(p["mix"], hn, cfg)
    h = h + mix
    aux = jnp.zeros((), jnp.float32)
    if _has_ffn(kind):
        y, metrics = _ffn_apply(p["ffn"], _norm(p["norm2"], h, cfg), cfg)
        h = h + y
        aux = aux + sum(
            (v for k, v in metrics.items() if k in ("moe_aux", "moe_z")),
            jnp.zeros((), jnp.float32),
        )
    return h, aux


def block_make_cache(kind, batch, s_max, cfg):
    if kind in ("attn", "local_attn"):
        if cfg.mla is not None:
            return MLA.make_mla_cache(batch, s_max, cfg)
        size = min(s_max, cfg.window) if (kind == "local_attn" and cfg.window) else s_max
        return A.make_kv_cache(batch, size, cfg.n_kv_heads, cfg.d_head, jnp.dtype(cfg.dtype))
    if kind == "xattn":
        return {
            "k": jnp.zeros((batch, cfg.n_img_tokens, cfg.n_kv_heads, cfg.d_head), jnp.dtype(cfg.dtype)),
            "v": jnp.zeros((batch, cfg.n_img_tokens, cfg.n_kv_heads, cfg.d_head), jnp.dtype(cfg.dtype)),
        }
    if kind == "ssm":
        return SSM.make_ssm_cache(batch, cfg)
    if kind == "rec":
        return REC.make_rglru_cache(batch, cfg)
    raise ValueError(kind)


def block_fill_cache(kind, p, h, positions, cache, cfg, ctx):
    """Prefill: run the block AND populate its cache. Returns (h, aux, cache).

    The mixer input is norm1(h); caches are filled from exactly that stream,
    and SSM / RG-LRU thread their true final recurrent state out of the
    train-path scan (exact prefill->decode handoff, verified by
    tests/test_serving_consistency.py)."""
    hn = _norm(p["norm1"], h, cfg)
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "local_attn"):
        win = cfg.window if kind == "local_attn" else None
        if cfg.mla is not None:
            cache = MLA.mla_fill_cache(p["mix"], hn, positions, cache, cfg)
            mix = MLA.mla_train(p["mix"], hn, positions, cfg)
        else:
            cache = A.fill_kv_cache(p["mix"], hn, positions, cache, cfg)
            mix = A.self_attention_train(p["mix"], hn, positions, cfg, window=win)
    elif kind == "xattn":
        b, n = ctx.shape[0], ctx.shape[1]
        k = P.dense_apply(p["mix"]["k"], ctx).reshape(b, n, cfg.n_kv_heads, cfg.d_head)
        v = P.dense_apply(p["mix"]["v"], ctx).reshape(b, n, cfg.n_kv_heads, cfg.d_head)
        cache = {"k": k, "v": v}
        mix = A.cross_attention_apply(p["mix"], hn, ctx, cfg)
    elif kind == "ssm":
        mix, cache = SSM.ssm_train(p["mix"], hn, cfg, return_state=True)
    elif kind == "rec":
        mix, cache = REC.rglru_train(p["mix"], hn, cfg, return_state=True)
    else:
        raise ValueError(kind)
    h = h + mix
    if _has_ffn(kind):
        y, metrics = _ffn_apply(p["ffn"], _norm(p["norm2"], h, cfg), cfg)
        h = h + y
        aux = aux + sum(
            (v for k, v in metrics.items() if k in ("moe_aux", "moe_z")),
            jnp.zeros((), jnp.float32),
        )
    return h, aux, cache


def block_decode(kind, p, h, cache, pos, cfg, ctx):
    hn = _norm(p["norm1"], h, cfg)
    if kind in ("attn", "local_attn"):
        win = cfg.window if kind == "local_attn" else None
        if cfg.mla is not None:
            mix, cache = MLA.mla_decode(p["mix"], hn, cache, pos, cfg)
        else:
            mix, cache = A.self_attention_decode(p["mix"], hn, cache, pos, cfg, window=win)
    elif kind == "xattn":
        q = P.dense_apply(p["mix"]["q"], hn).reshape(
            hn.shape[0], 1, cfg.n_heads, cfg.d_head
        )
        n = cache["k"].shape[1]
        out = A.decode_attention(
            q, cache["k"], cache["v"], jnp.arange(n), jnp.asarray(n, jnp.int32),
            mma=cfg.mma_reductions,
        )
        mix = P.dense_apply(p["mix"]["o"], out.reshape(hn.shape[0], 1, -1))
        mix = jnp.tanh(p["mix"]["gate"].astype(jnp.float32)).astype(mix.dtype) * mix
    elif kind == "ssm":
        mix, cache = SSM.ssm_decode(p["mix"], hn, cache, cfg)
    elif kind == "rec":
        mix, cache = REC.rglru_decode(p["mix"], hn, cache, cfg)
    h = h + mix
    if _has_ffn(kind):
        y, _ = _ffn_apply(p["ffn"], _norm(p["norm2"], h, cfg), cfg)
        h = h + y
    return h, cache


# ------------------------------ full model ----------------------------------


def _pattern_units(cfg: ModelConfig):
    pat = tuple(cfg.block_pattern)
    n_units = cfg.n_layers // len(pat)
    tail = tuple(pat[: cfg.n_layers % len(pat)])
    return pat, n_units, tail


def init_params(key, cfg: ModelConfig):
    """Returns (params, axes). Unit params stacked for lax.scan."""
    pat, n_units, tail = _pattern_units(cfg)
    ks = P.split(key, 6)
    dt = jnp.dtype(cfg.dtype)
    params, axes = {}, {}
    nbooks = max(1, cfg.n_codebooks)
    if cfg.n_codebooks:
        tbl = (jax.random.normal(ks[0], (nbooks, cfg.vocab_size, cfg.d_model), jnp.float32)
               * cfg.d_model**-0.5).astype(dt)
        params["embed"] = {"table": tbl}
        axes["embed"] = {"table": (None, "vocab", "embed")}
    else:
        params["embed"], axes["embed"] = P.embed_init(
            ks[0], cfg.vocab_size, cfg.d_model, dt
        )

    def unit_init(k):
        kks = P.split(k, len(pat))
        ps, as_ = {}, {}
        for i, kind in enumerate(pat):
            ps[f"pos{i}"], as_[f"pos{i}"] = block_init(kind, kks[i], cfg)
        return ps, as_

    params["units"], axes["units"] = P.stack_init(unit_init, ks[1], n_units)
    if tail:
        tp, ta = {}, {}
        tks = P.split(ks[2], len(tail))
        for i, kind in enumerate(tail):
            tp[f"pos{i}"], ta[f"pos{i}"] = block_init(kind, tks[i], cfg)
        params["tail"], axes["tail"] = tp, ta
    params["final_norm"], axes["final_norm"] = P.norm_init(cfg.norm, cfg.d_model, dt)
    if not cfg.tie_embeddings:
        nv = P.padded_vocab(cfg.vocab_size)
        if cfg.n_codebooks:
            head = (jax.random.normal(
                ks[3], (nbooks, cfg.d_model, nv), jnp.float32
            ) * cfg.d_model**-0.5).astype(dt)
            params["head"] = {"w": head}
            axes["head"] = {"w": (None, None, "vocab")}
        else:
            # d_model dim NOT FSDP-sharded (see params.embed_init note)
            params["head"], axes["head"] = P.dense_init(
                ks[3], cfg.d_model, nv, (None, "vocab"), dt
            )
    return params, axes


def _embed(params, cfg, tokens):
    if cfg.n_codebooks:
        # (B, S, K) codebook streams summed (MusicGen-style input fusion)
        tbl = params["embed"]["table"]
        parts = [tbl[k][tokens[..., k]] for k in range(cfg.n_codebooks)]
        return functools.reduce(jnp.add, parts)
    return params["embed"]["table"][tokens]


def _mask_pad_logits(logits, cfg):
    """Vocab rows are padded for sharding (params.padded_vocab); pad logits
    are masked so softmax/CE/argmax are exactly the unpadded math."""
    nv = logits.shape[-1]
    if nv == cfg.vocab_size:
        return logits
    pad_mask = jnp.arange(nv) >= cfg.vocab_size
    return jnp.where(pad_mask, -1e30, logits)


def _head(params, cfg, h):
    if cfg.tie_embeddings:
        logits = jnp.einsum(
            "bsd,vd->bsv", h.astype(jnp.float32),
            params["embed"]["table"].astype(jnp.float32),
        )
    elif cfg.n_codebooks:
        logits = jnp.einsum(
            "bsd,kdv->bskv", h.astype(jnp.float32),
            params["head"]["w"].astype(jnp.float32),
        )
    else:
        logits = jnp.einsum(
            "bsd,dv->bsv", h.astype(jnp.float32),
            params["head"]["w"].astype(jnp.float32),
        )
    if cfg.logits_softcap:
        c = cfg.logits_softcap
        logits = jnp.tanh(logits / c) * c
    return _mask_pad_logits(logits, cfg)


def _head_public(params, cfg, h):
    """Public logits contract: exactly vocab_size entries. The chunked loss
    keeps the padded (masked) form to avoid resharding per chunk."""
    return _head(params, cfg, h)[..., : cfg.vocab_size]


def forward_hidden(params, cfg: ModelConfig, tokens, ctx=None):
    """Backbone forward to the final normed hidden state (no head projection
    -- the chunked loss applies the head per seq tile). -> (h, aux)."""
    pat, n_units, tail = _pattern_units(cfg)
    h = CTX.constrain(_embed(params, cfg, tokens))
    b, s = h.shape[0], h.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def unit_fn(carry, unit_params):
        hh, aux = carry
        for i, kind in enumerate(pat):
            hh, a = block_train(kind, unit_params[f"pos{i}"], hh, positions, cfg, ctx)
            hh = CTX.constrain(hh)
            aux = aux + a
        return (hh, aux), None

    body = jax.checkpoint(unit_fn) if cfg.remat else unit_fn
    (h, aux), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)), params["units"])
    for i, kind in enumerate(tail):
        h, a = block_train(kind, params["tail"][f"pos{i}"], h, positions, cfg, ctx)
        h = CTX.constrain(h)
        aux = aux + a
    h = _norm(params["final_norm"], h, cfg)
    return h, aux


def forward(params, cfg: ModelConfig, tokens, ctx=None):
    """Teacher-forcing forward. tokens: (B, S) or (B, S, K). -> (logits, aux)."""
    h, aux = forward_hidden(params, cfg, tokens, ctx)
    return _head_public(params, cfg, h), aux


def make_caches(cfg: ModelConfig, batch: int, s_max: int):
    pat, n_units, tail = _pattern_units(cfg)

    def unit_cache(_):
        return {
            f"pos{i}": block_make_cache(kind, batch, s_max, cfg)
            for i, kind in enumerate(pat)
        }

    units = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_units,) + x.shape).copy()
        if n_units else x[None][:0],
        unit_cache(None),
    )
    caches = {"units": units}
    if tail:
        caches["tail"] = {
            f"pos{i}": block_make_cache(kind, batch, s_max, cfg)
            for i, kind in enumerate(tail)
        }
    return caches


def prefill(params, cfg: ModelConfig, tokens, caches, ctx=None):
    """Run the prompt, filling caches. Returns (last-token logits, caches)."""
    pat, n_units, tail = _pattern_units(cfg)
    h = _embed(params, cfg, tokens)
    b, s = h.shape[0], h.shape[1]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def unit_fn(carry, xs):
        hh, aux, stacked = carry
        unit_params, i = xs
        unit_cache = jax.tree.map(
            lambda c: jax.lax.dynamic_index_in_dim(c, i, 0, keepdims=False),
            stacked,
        )
        new_cache = {}
        for j, kind in enumerate(pat):
            hh, a, new_cache[f"pos{j}"] = block_fill_cache(
                kind, unit_params[f"pos{j}"], hh, positions,
                unit_cache[f"pos{j}"], cfg, ctx,
            )
            hh = CTX.constrain(hh)
            aux = aux + a
        stacked = jax.tree.map(
            lambda c, nc: jax.lax.dynamic_update_index_in_dim(
                c, nc.astype(c.dtype), i, 0
            ),
            stacked,
            new_cache,
        )
        return (hh, aux, stacked), None

    (h, _, new_units), _ = jax.lax.scan(
        unit_fn, (h, jnp.zeros((), jnp.float32), caches["units"]),
        (params["units"], jnp.arange(n_units)),
    )
    out_caches = {"units": new_units}
    if tail:
        tc = {}
        for i, kind in enumerate(tail):
            h, _, tc[f"pos{i}"] = block_fill_cache(
                kind, params["tail"][f"pos{i}"], h, positions,
                caches["tail"][f"pos{i}"], cfg, ctx,
            )
        out_caches["tail"] = tc
    h = _norm(params["final_norm"], h, cfg)
    return _head_public(params, cfg, h[:, -1:]), out_caches


def decode_step(params, cfg: ModelConfig, token_t, caches, pos, ctx=None):
    """One token step. token_t: (B, 1) or (B, 1, K); pos: scalar int32.
    Returns (logits (B,1,...), new_caches).

    The stacked unit caches travel in the scan CARRY and are updated with
    dynamic_update_index -- a single buffer XLA updates in place. (Passing
    them as scan xs/ys double-buffers the whole KV cache per step: +8 GB/dev
    on deepseek decode_32k, caught by the dry-run memory analysis.)"""
    pat, n_units, tail = _pattern_units(cfg)
    h = _embed(params, cfg, token_t)

    def unit_fn(carry, xs):
        hh, stacked = carry
        unit_params, i = xs
        unit_cache = jax.tree.map(
            lambda c: jax.lax.dynamic_index_in_dim(c, i, 0, keepdims=False),
            stacked,
        )
        new_cache = {}
        for j, kind in enumerate(pat):
            hh, new_cache[f"pos{j}"] = block_decode(
                kind, unit_params[f"pos{j}"], hh, unit_cache[f"pos{j}"], pos, cfg, ctx
            )
            hh = CTX.constrain(hh)
        stacked = jax.tree.map(
            lambda c, nc: jax.lax.dynamic_update_index_in_dim(
                c, nc.astype(c.dtype), i, 0
            ),
            stacked,
            new_cache,
        )
        return (hh, stacked), None

    (h, new_units), _ = jax.lax.scan(
        unit_fn, (h, caches["units"]),
        (params["units"], jnp.arange(n_units)),
    )
    out_caches = {"units": new_units}
    if tail:
        tc = {}
        for i, kind in enumerate(tail):
            h, tc[f"pos{i}"] = block_decode(
                kind, params["tail"][f"pos{i}"], h, caches["tail"][f"pos{i}"],
                pos, cfg, ctx,
            )
        out_caches["tail"] = tc
    h = _norm(params["final_norm"], h, cfg)
    return _head_public(params, cfg, h), out_caches
