"""Modality frontend STUBS, per the assignment spec.

``[audio]`` / ``[vlm]`` entries define the transformer *backbone* only; the
frontend supplies precomputed embeddings through ``input_specs()``:

  musicgen-medium      -- EnCodec tokenization is upstream; the model input
                          is the (B, S, n_codebooks) token grid itself, so
                          the "frontend" here is just the codebook summation
                          implemented in model._embed.
  llama-3.2-vision-11b -- the ViT tower is upstream; input_specs provides
                          (B, n_img_tokens, d_model) patch embeddings that
                          the interleaved cross-attention layers consume.

For runnable examples/tests, synth_* generate deterministic stand-ins.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def synth_image_embeds(key, batch: int, n_tokens: int, d_model: int, dtype=jnp.bfloat16):
    return (jax.random.normal(key, (batch, n_tokens, d_model), jnp.float32)).astype(dtype)


def synth_codebook_tokens(key, batch: int, seq: int, n_books: int, vocab: int):
    return jax.random.randint(key, (batch, seq, n_books), 0, vocab, jnp.int32)
