"""Mixture-of-Experts FFN: top-k routing with sort-based capacity dispatch.

The dispatch itself is reduction-as-matmul in the paper's spirit: tokens are
gathered per-expert into a dense (E, C, d) block so the expert FFNs run as
batched MXU einsums, and the combine is a gate-weighted segment reduction.
Router softmax and the load-balance statistics (per-expert token fractions,
mean gate mass -- arithmetic reductions over all tokens) ride the MMA path.

Expert-parallel sharding: the leading E axis of every expert weight carries
the "experts" logical axis -> mesh "model" axis (EP).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import reduce as R
from repro.models import context as CTX
from repro.models import layers as L
from repro.models import params as P


def _data_degree() -> int:
    sh = CTX.get_activation_sharding()
    if sh is None:
        return 1
    spec0 = sh.spec[0] if len(sh.spec) else None
    if spec0 is None:
        return 1
    axes = spec0 if isinstance(spec0, tuple) else (spec0,)
    deg = 1
    for ax in axes:
        deg *= sh.mesh.shape[ax]
    return deg


def _model_degree() -> int:
    sh = CTX.get_activation_sharding()
    if sh is None or "model" not in sh.mesh.shape:
        return 1
    return sh.mesh.shape["model"]


# Perf-loop switch: explicit shard_map dispatch/combine vs GSPMD-constrained.
# MEASURED (EXPERIMENTS.md Perf iteration 2): shard_map = 5738 MB static loop
# wire vs 5537 MB constrained on dbrx train_4k -- hypothesis REFUTED (GSPMD's
# boundary reshards around the manual region offset the dispatch savings), so
# the constrained path is the default; the switch stays for future meshes.
USE_SHARD_MAP_DISPATCH = False


def moe_init(key, cfg):
    e = cfg.moe
    d = cfg.d_model
    ks = P.split(key, 4)
    dt = jnp.dtype(cfg.dtype)

    def expert_w(key, din, dout):
        return (
            jax.random.normal(key, (e.n_experts, din, dout), jnp.float32) * din**-0.5
        ).astype(dt)

    params = {
        "router": (jax.random.normal(ks[0], (d, e.n_experts), jnp.float32) * d**-0.5
                   ).astype(jnp.float32),  # router stays f32 (routing stability)
        "gate": expert_w(ks[1], d, e.d_ff_expert),
        "up": expert_w(ks[2], d, e.d_ff_expert),
        "down": expert_w(ks[3], e.d_ff_expert, d),
    }
    axes = {
        "router": ("embed", None),
        "gate": ("experts", "embed", "ffn"),
        "up": ("experts", "embed", "ffn"),
        "down": ("experts", "ffn", "embed"),
    }
    if cfg.ffn_kind != "swiglu":
        params.pop("gate")
        axes.pop("gate")
    return params, axes


def _dispatch_row(expert_ix, gate_vals, n_experts: int, cap: int, backend=None):
    """Per-group dispatch: (S, k) routed pairs -> (E, C) slot tables.

    Runs entirely within one routing group (one sequence), so under GSPMD it
    never crosses the data axis -- this is GShard's group-wise routing, and
    it is what keeps MoE dispatch local (global-argsort dispatch replicates
    the token tensor across the mesh; caught by the dry-run, see DESIGN.md).
    """
    s, k = expert_ix.shape
    flat_expert = expert_ix.reshape(-1)                      # (S*k,)
    flat_token = jnp.repeat(jnp.arange(s), k)
    flat_gate = gate_vals.reshape(-1)
    order = jnp.argsort(flat_expert, stable=True)
    se, st, sg = flat_expert[order], flat_token[order], flat_gate[order]
    # Expert slot bases via the engine scan: the EXCLUSIVE prefix of the
    # per-expert routed counts equals searchsorted(se, arange(E)) on the
    # sorted keys, and counts < 2^24 make the f32 prefix integer-exact.
    counts = jax.ops.segment_sum(
        jnp.ones_like(se, jnp.float32), se, num_segments=n_experts
    )
    start = R.scan(counts, inclusive=False, backend=backend).astype(jnp.int32)
    within = jnp.arange(se.size) - start[se]
    keep = within < cap
    slot = jnp.where(keep, se * cap + within, n_experts * cap)  # overflow slot
    slot_token = jnp.full((n_experts * cap + 1,), s, jnp.int32)
    slot_token = slot_token.at[slot].set(jnp.where(keep, st, s).astype(jnp.int32))
    slot_gate = jnp.zeros((n_experts * cap + 1,), jnp.float32)
    slot_gate = slot_gate.at[slot].set(jnp.where(keep, sg, 0.0))
    return (
        slot_token[:-1].reshape(n_experts, cap),
        slot_gate[:-1].reshape(n_experts, cap),
        keep,
    )


def moe_apply(p, x, cfg):
    """x: (B, S, d) -> (y, aux_metrics).

    Group-wise top-k routing (groups = sequences): each batch row routes its
    S tokens into (E, C_row) capacity slots locally; expert FFNs run as
    (B, E, C, d) einsums sharded batch->data, experts->model (EP). Capacity-
    dropped tokens pass through the residual unchanged."""
    e = cfg.moe
    b, s, d = x.shape
    logits = x.astype(jnp.float32) @ p["router"]             # (B, S, E)
    probs = L.softmax_mma(logits, mma=cfg.mma_reductions)
    gate_vals, expert_ix = jax.lax.top_k(probs, e.top_k)     # (B, S, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9
    )
    cap = int(max(1, round(s * e.top_k / e.n_experts * e.capacity_factor)))

    # Dispatch offsets route through the engine scan. The site is vmapped,
    # so Pallas/segmented backends degrade to the mma_jnp einsum route
    # (identical f32-exact integer prefixes, no pallas_call under vmap).
    _rb = R.backend_for_flags(cfg.mma_reductions)
    _sb = _rb if _rb in ("xla", "mma_jnp") else "mma_jnp"
    slot_token, slot_gate, keep = jax.vmap(
        lambda ei, gv: _dispatch_row(ei, gv, e.n_experts, cap, backend=_sb)
    )(expert_ix, gate_vals)                                   # (B,E,C) x2, (B,S*k)

    xpad = jnp.concatenate([x, jnp.zeros((b, 1, d), x.dtype)], 1)  # (B,S+1,d)
    # Dispatch gather runs in an explicitly-local shard_map region: batch
    # rows stay on their data shard, and each model rank gathers only ITS
    # experts' slots (slot tables sharded over the model axis). GSPMD's
    # gather partitioner otherwise replicates the activations in f32
    # ("involuntary full rematerialization"; Perf iteration 2).
    from jax.sharding import PartitionSpec as P

    bsp = CTX.batch_axis_entry()
    use_sm = (
        USE_SHARD_MAP_DISPATCH
        and bsp is not None
        and b % max(1, _data_degree()) == 0
        and e.n_experts % _model_degree() == 0
    )
    if use_sm:
        gfn = CTX.shard_map_specs(
            jax.vmap(lambda xr, ix: xr[ix]),
            in_specs=(P(bsp, None, None), P(bsp, "model", None)),
            out_specs=P(bsp, "model", None, None),
        )
        gathered = gfn(xpad, slot_token)                           # (B,E,C,d)
    else:
        gathered = jax.vmap(lambda xr, ix: xr[ix])(xpad, slot_token)
        gathered = CTX.constrain_moe_dispatch(gathered)

    # ---- expert FFNs as batched einsums (MXU; E sharded over model) ----
    if cfg.ffn_kind == "swiglu":
        h = jax.nn.silu(
            jnp.einsum("becd,edf->becf", gathered, p["gate"].astype(x.dtype))
        ) * jnp.einsum("becd,edf->becf", gathered, p["up"].astype(x.dtype))
    else:
        h = jax.nn.gelu(
            jnp.einsum("becd,edf->becf", gathered, p["up"].astype(x.dtype))
        )
    yexp = CTX.constrain_moe_dispatch(
        jnp.einsum("becf,efd->becd", h, p["down"].astype(x.dtype))
    )  # (B,E,C,d)

    # ---- gate-weighted combine back to tokens ----
    # Local per-expert-shard segment-sum, then ONE explicit psum over the
    # model axis of the token-space partials: the EP combine moves S*d
    # activations once instead of GSPMD's E*C*d f32 reshard (Perf iter. 2).
    # slot_gate is cast to the activation dtype BEFORE the multiply: an f32
    # gate here promotes the whole combine -- and via its cotangents every
    # FSDP weight gather in the backward pass -- to f32, doubling wire bytes
    # (Perf iteration 2b).
    yflat = (yexp * slot_gate[..., None].astype(yexp.dtype)).reshape(b, -1, d)
    seg = lambda yr, ix: jax.ops.segment_sum(yr, ix, num_segments=s + 1)
    if use_sm:
        def combine(yfl, ix):
            partial = jax.vmap(seg)(yfl, ix)       # (B_loc, S+1, d) this shard
            return jax.lax.psum(partial, "model")

        sfn = CTX.shard_map_specs(
            combine,
            in_specs=(P(bsp, "model", None), P(bsp, "model")),
            out_specs=P(bsp, None, None),
        )
        y = sfn(yflat, slot_token.reshape(b, -1))[:, :s]
    else:
        y = jax.vmap(seg)(yflat, slot_token.reshape(b, -1))[:, :s]

    # ---- aux losses: reductions over all tokens (MMA path) ----
    # Both load-balance statistics (per-expert token fractions f_e and mean
    # gate mass P_e) are per-expert reductions over all B*S tokens; instead
    # of two separate launches they batch into ONE reduce_many row pass
    # (each statistic contributes E rows of B*S token values).
    ones_k = jax.nn.one_hot(expert_ix, e.n_experts, dtype=jnp.float32)  # (B,S,k,E)
    t = b * s
    counts = ones_k.sum(2)                                              # (B,S,E)
    tpe_sum, prob_sum = R.reduce_many(
        [jnp.moveaxis(counts, -1, 0).reshape(e.n_experts, -1),
         jnp.moveaxis(probs, -1, 0).reshape(e.n_experts, -1)],
        axis=-1,
        backend=_rb,
    )
    tokens_per_expert = tpe_sum / t                                     # f_e
    mean_prob = prob_sum / t                                            # P_e
    aux = e.n_experts * jnp.sum(tokens_per_expert * mean_prob)
    zloss = jnp.mean(jax.nn.logsumexp(logits, -1) ** 2)
    metrics = {
        "moe_aux": aux * e.aux_loss_weight,
        "moe_z": zloss * e.router_z_weight,
        "moe_drop_frac": 1.0 - jnp.sum(keep) / keep.size,
    }
    return y.astype(x.dtype), metrics
