"""Activation-sharding context.

GSPMD's solver, given FSDP-sharded weights and no activation constraints, is
free to replicate the batch and shard activations on d_model -- valid but
catastrophic (it turns data parallelism into redundant compute; caught by
the dry-run's collective analysis). The launcher pins the intended layout
here before tracing; `constrain` is a no-op when unset (CPU tests, 1
device). Model code calls `constrain(h)` at unit boundaries -- GSPMD
propagates the layout through block internals from there.
"""

from __future__ import annotations

import jax

_ACT_SHARDING = None  # NamedSharding for (batch, seq, d_model) activations


def set_activation_sharding(sharding) -> None:
    global _ACT_SHARDING
    _ACT_SHARDING = sharding


def get_activation_sharding():
    return _ACT_SHARDING


def constrain(h: jax.Array) -> jax.Array:
    if _ACT_SHARDING is None or h.ndim != 3:
        return h
    return jax.lax.with_sharding_constraint(h, _ACT_SHARDING)


def shard_map_specs(fn, in_specs, out_specs):
    """shard_map under the active mesh context (None if no context). Used to
    bypass GSPMD's gather/scatter partitioner (which falls back to full
    replication for vmapped gathers -- 'involuntary full rematerialization')
    with explicitly-local dispatch/combine regions."""
    if _ACT_SHARDING is None:
        return None
    from repro.core.collectives import shard_map  # version-compat resolution

    mesh = _ACT_SHARDING.mesh
    try:
        return shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
    except TypeError:  # older jax: check_rep
        return shard_map(
            fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_rep=False,
        )


def batch_axis_entry():
    """The PartitionSpec entry for the batch dim (None if unsharded)."""
    if _ACT_SHARDING is None:
        return None
    return _ACT_SHARDING.spec[0] if len(_ACT_SHARDING.spec) else None


def constrain_moe_dispatch(t: jax.Array) -> jax.Array:
    """Pin the (B, E, C, d) expert-dispatch layout: batch over the data axes,
    experts over model (EP). Without this GSPMD reshards the vmapped gather
    through full replication (its 'involuntary full rematerialization' path;
    caught by the dry-run on the multi-pod mesh)."""
    if _ACT_SHARDING is None or t.ndim != 4:
        return t
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = _ACT_SHARDING.mesh
    bspec = _ACT_SHARDING.spec[0] if len(_ACT_SHARDING.spec) else None
    espec = "model" if t.shape[1] % mesh.shape["model"] == 0 else None
    return jax.lax.with_sharding_constraint(
        t, NamedSharding(mesh, P(bspec, espec, None, None))
    )
