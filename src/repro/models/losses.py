"""Training losses. The CE logsumexp denominator and the token-mean are the
two largest reductions in a step; both route through the unified reduction
engine (``repro.reduce``), which selects the paper's MMA path when
cfg.mma_reductions is on (Pallas fused CE under cfg.use_pallas)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import reduce as R


def cross_entropy_tokens(logits, labels, *, mma: bool, use_pallas: bool = False):
    """Per-token CE. logits: (..., V) f32; labels: (...,) int32."""
    if use_pallas:
        from repro.kernels import cross_entropy as ce_kernel

        return ce_kernel(logits, labels)
    lf = logits.astype(jnp.float32)
    m = jnp.max(lf, -1)
    e = jnp.exp(lf - m[..., None])
    denom = R.reduce(e, axis=-1, backend=R.backend_for_flags(mma))
    lse = m + jnp.log(jnp.maximum(denom, 1e-30))
    picked = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return lse - picked


def lm_loss(logits, labels, aux, cfg):
    """Mean next-token loss (+ MoE aux). Handles (B,S,V) and (B,S,K,V)."""
    per_tok = cross_entropy_tokens(
        logits, labels, mma=cfg.mma_reductions, use_pallas=cfg.use_pallas
    )
    mean = R.reduce(
        per_tok, kind="mean", backend=R.backend_for_flags(cfg.mma_reductions)
    )
    return mean + aux, {"ce": mean, "aux": aux}


def lm_loss_chunked(params, cfg, h, labels, aux, *, seq_chunk: int = 512):
    """Memory-bounded LM loss: the head projection + CE run inside a remat'd
    lax.scan over sequence chunks, so the (B, S, V) logits never exist -- peak
    extra memory is one (B, seq_chunk, V) f32 tile. This is what lets vocabs
    up to 256k train at seq 4096 inside v5e HBM (caught by the dry-run's
    memory_analysis; see EXPERIMENTS.md Dry-run notes).

    h: final normed hidden (B, S, d); labels: (B, S[, K])."""
    from repro.models.model import _head  # padded+masked head (no reshard)

    b, s, _ = h.shape
    chunk = min(seq_chunk, s)
    pad = (-s) % chunk
    hp = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, pad)) + ((0, 0),) * (labels.ndim - 2))
    # padded positions masked out of the mean
    mask = jnp.pad(jnp.ones((b, s), jnp.float32), ((0, 0), (0, pad)))
    nchunk = hp.shape[1] // chunk
    hc = hp.reshape(b, nchunk, chunk, -1).swapaxes(0, 1)
    lc = lp.reshape((b, nchunk, chunk) + lp.shape[2:]).swapaxes(0, 1)
    mc = mask.reshape(b, nchunk, chunk).swapaxes(0, 1)

    def body(acc, xs):
        hcb, lcb, mcb = xs
        logits = _head(params, cfg, hcb)
        per_tok = cross_entropy_tokens(
            logits, lcb, mma=cfg.mma_reductions, use_pallas=cfg.use_pallas
        )
        if per_tok.ndim == 3:  # codebook streams: mean over K
            per_tok = jnp.mean(per_tok, -1)
        per_tok = per_tok * mcb
        acc = acc + R.reduce(
            per_tok, backend=R.backend_for_flags(cfg.mma_reductions)
        )
        return acc, None

    total, _ = jax.lax.scan(
        jax.checkpoint(body), jnp.zeros((), jnp.float32), (hc, lc, mc)
    )
    mean = total / (b * s)
    return mean + aux, {"ce": mean, "aux": aux}
