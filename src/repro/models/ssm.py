"""Mamba-2 (SSD, state-space duality) block. [arXiv:2405.21060]

The SSD chunked algorithm is itself reduction-by-matmul: within a chunk the
output is a masked (C B^T) "attention" matmul and the chunk state is a
decayed sum of outer products -- both land on the MXU, which is why this
architecture is a natural citizen of an MMA-reduction framework. The
inter-chunk recurrence is a first-order scan (lax.scan over n_chunks).

Projections are split (z / xBC / dt) so each output lands on its own logical
axis and tensor-parallel sharding never slices across a concat boundary.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import reduce as R
from repro.models import layers as L
from repro.models import params as P


def _dims(cfg):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.headdim
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return s, d_in, nh, conv_dim


def ssm_init(key, cfg):
    s, d_in, nh, conv_dim = _dims(cfg)
    dt = jnp.dtype(cfg.dtype)
    ks = P.split(key, 8)
    z, az = P.dense_init(ks[0], cfg.d_model, d_in, ("embed", "inner"), dt)
    xbc, axbc = P.dense_init(ks[1], cfg.d_model, conv_dim, ("embed", "inner"), dt)
    dtp, adt = P.dense_init(ks[2], cfg.d_model, nh, ("embed", None), dt)
    out, aout = P.dense_init(ks[3], d_in, cfg.d_model, ("inner", "embed"), dt)
    conv_w = (jax.random.normal(ks[4], (s.conv_width, conv_dim), jnp.float32)
              * (s.conv_width**-0.5)).astype(dt)
    # dt bias via inverse softplus of uniform [dt_min, dt_max] (mamba init)
    u = jax.random.uniform(ks[5], (nh,), jnp.float32)
    dt0 = jnp.exp(u * (jnp.log(s.dt_max) - jnp.log(s.dt_min)) + jnp.log(s.dt_min))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))
    a_init = jax.random.uniform(ks[6], (nh,), jnp.float32, 1.0, 16.0)
    params = {
        "z": z, "xbc": xbc, "dt": dtp, "out": out,
        "conv_w": conv_w,
        "dt_bias": dt_bias,
        "A_log": jnp.log(a_init),
        "D": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.ones((d_in,), dt),
    }
    axes = {
        "z": az, "xbc": axbc, "dt": adt, "out": aout,
        "conv_w": (None, "inner"),
        "dt_bias": None, "A_log": None, "D": None,
        "norm_scale": ("inner",),
    }
    return params, axes


def _segsum(dA, backend=None):
    """(..., q) -> (..., q, q) lower-triangular cumulative-decay exponents."""
    q = dA.shape[-1]
    cs = R.scan(dA, axis=-1, backend=backend)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, backend=None):
    """SSD scan. x: (b,l,h,p); dt: (b,l,h); A: (h,); B,C: (b,l,g,n).
    Returns y: (b,l,h,p) and final state (b,h,p,n)."""
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    q = min(chunk, l)
    pad = (-l) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = x.shape[1] // q
    hpg = h // g  # heads per group
    xc = x.reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h)
    Bc = B.reshape(b, nc, q, g, n)
    Cc = C.reshape(b, nc, q, g, n)
    xdt = xc * dtc[..., None]
    dA = dtc * A  # (b,nc,q,h) ; A negative
    A_cum = R.scan(dA, axis=2, backend=backend)

    # -- intra-chunk (diagonal blocks): masked attention-like matmuls --
    Lmask = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2), backend=backend))  # (b,nc,h,q,q)
    CB = jnp.einsum("bcqgn,bckgn->bcgqk", Cc, Bc)           # (b,nc,g,q,k) MXU
    CB = jnp.repeat(CB, hpg, axis=2)                         # g -> h
    scores = CB * Lmask
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp", scores, xdt)  # MXU

    # -- chunk states: decayed outer-product reductions (MXU) --
    decay_to_end = jnp.exp(A_cum[:, :, -1:, :] - A_cum)     # (b,nc,q,h)
    if g == 1:
        # shared-B semantics via a size-1 summed index (no materialization)
        states = jnp.einsum("bcqin,bcqh,bcqhp->bchpn", Bc, decay_to_end, xdt)
    else:
        Bh = jnp.repeat(Bc, hpg, axis=3)
        states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn", Bh, decay_to_end, xdt)

    # -- inter-chunk recurrence --
    chunk_decay = jnp.exp(A_cum[:, :, -1, :])               # (b,nc,h)

    def step(carry, inp):
        s_c, dec = inp                                       # (b,h,p,n), (b,h)
        new = carry * dec[..., None, None] + s_c
        return new, carry                                    # emit *previous* state

    init = jnp.zeros((b, h, p, n), jnp.float32)
    final, prev_states = jax.lax.scan(
        step, init,
        (states.transpose(1, 0, 2, 3, 4).astype(jnp.float32),
         chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)       # (b,nc,h,p,n)

    # -- off-diagonal contribution: C_t . state_prev, decayed from chunk start
    state_decay = jnp.exp(A_cum)                             # (b,nc,q,h)
    if g == 1:
        y_off = jnp.einsum("bcqin,bchpn,bcqh->bcqhp", Cc, prev_states, state_decay)
    else:
        Ch = jnp.repeat(Cc, hpg, axis=3).reshape(b, nc, q, h, n)
        y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", Ch, prev_states, state_decay)

    y = (y_diag + y_off).reshape(b, nc * q, h, p)[:, :l]
    return y.astype(x.dtype), final


def ssm_train(p, x, cfg, return_state: bool = False):
    """Full Mamba-2 block, train/prefill. x: (B, L, d) -> (B, L, d)
    (or (out, cache) when return_state, for the prefill->decode handoff)."""
    s, d_in, nh, conv_dim = _dims(cfg)
    z = P.dense_apply(p["z"], x)
    xbc_raw = P.dense_apply(p["xbc"], x)
    dt_raw = P.dense_apply(p["dt"], x).astype(jnp.float32)
    xbc = jax.nn.silu(L.causal_conv1d(xbc_raw, p["conv_w"]))
    xs = xbc[..., :d_in]
    Bx = xbc[..., d_in : d_in + s.n_groups * s.d_state]
    Cx = xbc[..., d_in + s.n_groups * s.d_state :]
    b, l, _ = x.shape
    xh = xs.reshape(b, l, nh, s.headdim)
    Bh = Bx.reshape(b, l, s.n_groups, s.d_state).astype(jnp.float32)
    Ch = Cx.reshape(b, l, s.n_groups, s.d_state).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])              # (b,l,nh)
    A = -jnp.exp(p["A_log"])                                 # (nh,)
    y, final_state = ssd_chunked(
        xh.astype(jnp.float32), dt, A, Bh, Ch, s.chunk,
        backend=R.backend_for_flags(cfg.mma_reductions),
    )
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, l, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = L.norm_apply(
        "rmsnorm", {"scale": p["norm_scale"]}, y.astype(x.dtype),
        eps=cfg.norm_eps, mma=cfg.mma_reductions,
    )
    out = P.dense_apply(p["out"], y)
    if not return_state:
        return out
    # conv cache = last (K-1) pre-conv inputs (front-padded for short prompts)
    k = s.conv_width
    pad = max(0, (k - 1) - l)
    tail = jnp.pad(xbc_raw, ((0, 0), (pad, 0), (0, 0)))[:, -(k - 1):]
    return out, {"conv": tail, "state": final_state}


def make_ssm_cache(batch: int, cfg):
    s, d_in, nh, conv_dim = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_dim), jnp.dtype(cfg.dtype)),
        "state": jnp.zeros((batch, nh, s.headdim, s.d_state), jnp.float32),
    }


def ssm_decode(p, x_t, cache, cfg):
    """One decode step. x_t: (B, 1, d). O(1) state -- no KV growth."""
    s, d_in, nh, conv_dim = _dims(cfg)
    b = x_t.shape[0]
    xt = x_t[:, 0]
    z = P.dense_apply(p["z"], xt)
    xbc_t = P.dense_apply(p["xbc"], xt)
    dt_raw = P.dense_apply(p["dt"], xt).astype(jnp.float32)
    conv_state, y_conv = L.conv1d_step(cache["conv"], xbc_t, p["conv_w"])
    xbc = jax.nn.silu(y_conv.astype(jnp.float32))
    xs = xbc[..., :d_in]
    Bx = xbc[..., d_in : d_in + s.n_groups * s.d_state]
    Cx = xbc[..., d_in + s.n_groups * s.d_state :]
    xh = xs.reshape(b, nh, s.headdim)
    Bh = Bx.reshape(b, s.n_groups, s.d_state)
    Ch = Cx.reshape(b, s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])              # (b,nh)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A)                                  # (b,nh)
    # state <- decay * state + dt * x (outer) B   (g==1 broadcast over heads)
    Bb = jnp.broadcast_to(Bh[:, :1, :], (b, 1, s.d_state))
    state = cache["state"] * decay[..., None, None] + jnp.einsum(
        "bh,bhp,bin->bhpn", dt, xh.astype(jnp.float32), Bb
    )
    y = jnp.einsum("bin,bhpn->bhp", Ch, state)               # C . state
    y = y + p["D"][None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, d_in) * jax.nn.silu(z.astype(jnp.float32))
    y = L.norm_apply(
        "rmsnorm", {"scale": p["norm_scale"]}, y.astype(x_t.dtype),
        eps=cfg.norm_eps, mma=cfg.mma_reductions,
    )
    out = P.dense_apply(p["out"], y)[:, None, :]
    return out, {"conv": conv_state, "state": state}
