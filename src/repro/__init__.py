"""repro: a jax_pallas reproduction of the tensor-core reduction paper.

Top-level convenience exports, resolved LAZILY so that ``import repro``
stays free of jax/kernel import cost (launch scripts import submodules
directly and must not pay for the whole engine at CLI-parse time):

  repro.scan            -- prefix sums on the engine (repro.reduce.scan)
  repro.reduce          -- the reduction package (also importable directly)
"""

_LAZY = {
    "scan": ("repro.reduce.scan", "scan"),
    "ScanPlan": ("repro.reduce.plan", "ScanPlan"),
    "scan_plan_for": ("repro.reduce.plan", "scan_plan_for"),
}


def __getattr__(name):
    try:
        module, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}"
        ) from None
    import importlib

    return getattr(importlib.import_module(module), attr)


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
